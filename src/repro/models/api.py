"""Unified model API: init / forward / loss / prefill / decode / cache.

Every assigned architecture is driven through these six functions; the
launcher, trainer, serving engine and dry-run all sit on top of them.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.layers import Maker, norm_apply, norm_init
from repro.parallel.sharding import NO_RULES, Rules

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _build(cfg, mk: Maker, key=None) -> Dict[str, Any]:
    vp = tfm.padded_vocab(cfg.vocab)
    d = cfg.d_model
    kinds = tfm.pattern_for(cfg)
    n_super, tail = tfm.layer_plan(cfg)
    p: Dict[str, Any] = {
        "embed": mk((vp, d), "wvocab,wembed", scale=0.02),
        "final_norm": norm_init(mk, d, cfg.norm),
        "blocks": tfm.stack_init(
            mk, cfg, kinds, n_super, tail,
            key=None if mk.mode == "axes" else jax.random.fold_in(key, 1)),
    }
    if not cfg.tie_embeddings:
        p["head"] = mk((d, vp), "wembed,wvocab", scale=d ** -0.5)
    if cfg.is_encdec:
        ek = None if mk.mode == "axes" else jax.random.fold_in(key, 2)
        p["enc"] = {
            "blocks": tfm.stack_init(mk, cfg, ("enc",), cfg.enc_layers, (),
                                     key=ek),
            "final_norm": norm_init(mk, d, cfg.norm),
        }
    return p


def init_params(cfg, key) -> Dict[str, Any]:
    mk = Maker("init", key, jnp.dtype(cfg.dtype))
    return _build(cfg, mk, key)


def param_axes(cfg) -> Dict[str, Any]:
    return _build(cfg, Maker("axes"))


def param_shapes(cfg):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _logits(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)


def _encode(cfg, params, enc_embeds, rules):
    x, _, _ = tfm.stack_apply(cfg, params["enc"]["blocks"], enc_embeds,
                              ("enc",), (), rules=rules)
    return norm_apply(params["enc"]["final_norm"], x, cfg.norm)


def forward_hidden(cfg, params, batch: Dict[str, Any], *,
                   rules: Rules = NO_RULES, want_cache: bool = False,
                   max_len=None, prefix_kv=None, prefix_len=None,
                   length=None, paged_kv: bool = False):
    """batch: {tokens [, frontend_embeds | enc_embeds]} -> (hidden, caches,
    aux). Sequence layout for VLM: [frontend_embeds | token embeds].

    prefix_kv + prefix_len (traced scalar): `tokens` are the SUFFIX of a
    request whose first prefix_len tokens' KV is being reused from the
    paged pool (prefix sharing); positions and causal masks are offset
    accordingly. Attention-only stacks only — recurrent state cannot be
    reconstructed from cached KV.

    length (scalar/(B,), may be traced) + paged_kv: bucketed prefill for
    stacks with recurrent / windowed state — `tokens` is right-padded to
    a bucket size and only the first `length` are real. Recurrent blocks
    mask their state updates past `length` (the returned state is the
    state at length - 1) and local_attn returns full-sequence kv for the
    paged window scatter instead of a ring buffer (see block_apply)."""
    kinds = tfm.pattern_for(cfg)
    _, tail = tfm.layer_plan(cfg)
    if prefix_kv is not None:
        assert set(kinds) <= set(PAGEABLE_KINDS), \
            f"prefix reuse needs an attention-only stack, got {kinds}"
    x = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.frontend == "patch" and "frontend_embeds" in batch:
        x = jnp.concatenate(
            [batch["frontend_embeds"].astype(x.dtype), x], axis=1)
    x = rules.cons(x, "batch,seq,embed")
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    if prefix_len is not None:
        positions = positions + jnp.asarray(prefix_len, jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        enc_out = _encode(cfg, params, batch["enc_embeds"].astype(x.dtype),
                          rules)
    x, caches, aux = tfm.stack_apply(cfg, params["blocks"], x, kinds, tail,
                                     rules=rules, positions=positions,
                                     enc_out=enc_out, want_cache=want_cache,
                                     max_len=max_len, prefix_kv=prefix_kv,
                                     prefix_len=prefix_len, length=length,
                                     paged_kv=paged_kv)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return x, caches, aux


def forward(cfg, params, batch: Dict[str, Any], *, rules: Rules = NO_RULES,
            want_cache: bool = False, max_len=None):
    """Full-sequence logits (small models / tests; training uses the
    blockwise-CE path in loss_fn to avoid materializing (B, S, vocab))."""
    x, caches, aux = forward_hidden(cfg, params, batch, rules=rules,
                                    want_cache=want_cache, max_len=max_len)
    logits = _logits(cfg, params, x)
    logits = rules.cons(logits, "batch,seq,vocab")
    return logits, caches, aux


CE_CHUNK = 512


def _ce_chunk(cfg, params, x, labels, rules):
    """CE over one sequence chunk; logits (B, c, Vp) live only inside."""
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    logits = rules.cons(logits, "batch,seq,vocab")
    vp = logits.shape[-1]
    if vp > cfg.vocab:
        logits = jnp.where(jnp.arange(vp) < cfg.vocab, logits, -1e30)
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, lab[..., None], -1)[..., 0]
    return ((lse - ll) * mask).sum(), mask.sum()


def loss_fn(cfg, params, batch, *, rules: Rules = NO_RULES):
    """Next-token CE (labels aligned: labels[t] is the target of logits[t]).
    Blockwise over sequence chunks: full (B, S, vocab) logits are never
    materialized (checkpointed scan recomputes per-chunk logits in bwd).
    VLM: loss only over the text segment (last `len(labels)` positions)."""
    x, _, aux = forward_hidden(cfg, params, batch, rules=rules)
    labels = batch["labels"]
    if x.shape[1] != labels.shape[1]:  # VLM frontend positions carry no loss
        x = x[:, -labels.shape[1]:]
    B, S, _ = x.shape
    c = min(CE_CHUNK, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // c
    xc = x.reshape(B, nc, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        xs, ls = inp
        t, n = jax.checkpoint(
            lambda a, b: _ce_chunk(cfg, params, a, b, rules))(xs, ls)
        return (tot + t, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xc, lc))
    ce = tot / jnp.maximum(cnt, 1.0)
    loss = ce
    if cfg.moe is not None and cfg.moe.num_experts > 0:
        loss = loss + 0.01 * aux["lb_loss"] + 0.001 * aux["z_loss"]
    return loss, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(cfg, params, batch, *, rules: Rules = NO_RULES, max_len=None,
            length=None, prefix_kv=None, prefix_len=None,
            paged_kv: bool = False):
    """Run the full prompt; returns (last_logits, cache, next_pos). Full-attn
    kv caches are padded out to `max_len` slots for subsequent decoding.
    Logits are computed for the LAST position only (the (B, S, vocab) tensor
    is never materialized — PDMA-style residency at the serving level).

    `length` (scalar or (B,) int32, may be traced) marks the number of REAL
    tokens when `tokens` is right-padded to a bucket size: logits are taken
    at position length-1 and next_pos = length. Causal masking already
    keeps positions < length independent of the padding, so one trace
    serves every prompt length in the bucket (the serving engine's
    mixed-grained-prefetch analogue). Stacks with recurrent / windowed
    state additionally need ``paged_kv=True``: recurrent blocks then mask
    state updates past ``length`` (so the returned state is the state at
    length - 1 — padding never leaks into it) and local_attn blocks
    return full-sequence kv for the paged window scatter; WITHOUT
    paged_kv those callers must pass exact-length tokens (the dense
    engine's ring buffers carry padding into their state otherwise).

    prefix_kv + prefix_len (traced): suffix-only prefill — `tokens` and
    `length` describe only the part of the prompt AFTER a prefix whose KV
    is reused from the paged pool (see forward_hidden / prefix_cache.py).
    The returned cache holds the suffix k/v only; returned pos counts
    suffix tokens (callers add prefix_len)."""
    x, caches, _ = forward_hidden(cfg, params, batch, rules=rules,
                                  want_cache=True, max_len=max_len,
                                  prefix_kv=prefix_kv, prefix_len=prefix_len,
                                  length=length if paged_kv else None,
                                  paged_kv=paged_kv)
    B, S = x.shape[0], x.shape[1]
    if length is None:
        logits = _logits(cfg, params, x[:, -1:])[:, 0]
        pos = jnp.full((B,), S, jnp.int32)
    else:
        length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
        idx = jnp.clip(length - 1, 0, S - 1)[:, None, None]
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (B, 1, x.shape[-1])), axis=1)
        logits = _logits(cfg, params, xl)[:, 0]
        pos = length
    return logits, caches, pos


def decode_step(cfg, params, cache, tokens, pos, *,
                rules: Rules = NO_RULES, block_table=None,
                win_block_table=None):
    """tokens: (B, T) int32 — T == 1 for plain decode, T > 1 for a
    speculative multi-token verify block (paged caches only; token t of
    request b sits at absolute position pos[b] + t). pos: (B,) position of
    the FIRST new token. -> (logits, new_cache); logits are (B, vocab)
    when T == 1 (the historical contract every serving loop relies on)
    and (B, T, vocab) when T > 1 — one row per block position, which is
    exactly what greedy speculative acceptance consumes.
    block_table: (B, n_blocks) int32 switches full-attention cache entries
    to the shared paged pool layout (see paged_cache_init); attention then
    runs the block-table indirection inside the Pallas flash-decode kernel
    (kernels/ops.paged_attention) unless cfg.paged_attn_impl == "gather"
    pins the dense-gather baseline. win_block_table: same for local_attn
    layers (sliding-window pages, recycled as they slide out of the
    window); without it local_attn runs the dense ring buffer —
    single-token only, so a T > 1 block on a windowed stack WITHOUT the
    paged window layout is rejected here with a ValueError naming the
    layer kind (instead of the bare shape assert it used to die with
    deep inside the jit trace)."""
    kinds = tfm.pattern_for(cfg)
    _, tail = tfm.layer_plan(cfg)
    if tokens.shape[1] > 1:
        present = dict.fromkeys(tuple(kinds) + tuple(tail))
        bad = [k for k, need in
               (("attn_mlp", block_table), ("attn_moe", block_table),
                ("local_attn", win_block_table))
               if k in present and need is None]
        bad += [k for k in ("dec", "enc") if k in present]
        if bad:
            raise ValueError(
                f"multi-token decode blocks (T={tokens.shape[1]}) need "
                f"every attention layer on a paged cache layout, but "
                f"layer kind(s) {bad} have none: pass block_table for "
                f"full attention and win_block_table for local_attn "
                f"(the dense ring buffer is single-token — it has "
                f"already overwritten the keys older block rows attend "
                f"to)")
    x = _embed_tokens(cfg, params, tokens)
    x = rules.cons(x, "batch,seq,embed")
    x, new_cache = tfm.stack_decode(cfg, params["blocks"], x, cache, pos,
                                    kinds, tail, rules=rules,
                                    block_table=block_table,
                                    win_block_table=win_block_table)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    if tokens.shape[1] == 1:
        logits = _logits(cfg, params, x)[:, 0]
        return rules.cons(logits, "batch,vocab"), new_cache
    logits = _logits(cfg, params, x)
    return rules.cons(logits, "batch,seq,vocab"), new_cache


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _block_cache_init(cfg, kind: str, batch: int, seq_len: int):
    from repro.models import griffin, ssm
    dt = jnp.dtype(cfg.kv_cache_dtype)   # int8 cache opt-in (§Perf C4)
    kv, hd = cfg.kv_heads, cfg.resolved_head_dim
    if kind in ("attn_mlp", "attn_moe"):
        return {"k": jnp.zeros((batch, seq_len, kv, hd), dt),
                "v": jnp.zeros((batch, seq_len, kv, hd), dt)}
    if kind == "dec":
        return {"k": jnp.zeros((batch, seq_len, kv, hd), dt),
                "v": jnp.zeros((batch, seq_len, kv, hd), dt),
                "xk": jnp.zeros((batch, seq_len, kv, hd), dt),
                "xv": jnp.zeros((batch, seq_len, kv, hd), dt)}
    if kind == "local_attn":
        w = cfg.hybrid.window  # ring buffer is always window-sized
        return {"k": jnp.zeros((batch, w, kv, hd), dt),
                "v": jnp.zeros((batch, w, kv, hd), dt)}
    if kind == "ssm":
        return ssm.ssm_cache_init(cfg, batch)
    if kind == "rglru":
        return griffin.rglru_cache_init(cfg, batch)
    raise ValueError(kind)


def cache_init(cfg, batch: int, seq_len: int):
    kinds = tfm.pattern_for(cfg)
    n_super, tail = tfm.layer_plan(cfg)

    def stacked(kind):
        one = _block_cache_init(cfg, kind, batch, seq_len)
        return jax.tree.map(
            lambda a: jnp.zeros((n_super,) + a.shape, a.dtype), one)

    scan = {str(j): stacked(k) for j, k in enumerate(kinds)} if n_super else {}
    tailc = [_block_cache_init(cfg, k, batch, seq_len) for k in tail]
    return {"scan": scan, "tail": tailc}


PAGEABLE_KINDS = ("attn_mlp", "attn_moe")       # full-attention page pools
WINDOW_KINDS = ("local_attn",)                  # sliding-window page pools
STATE_KINDS = ("ssm", "rglru")                  # fixed-size per-slot state
# every block kind the PagedServingEngine can host (encoder-decoder stays
# on the dense engine: cross-attention KV is per-request, not paged)
PAGED_SERVABLE_KINDS = PAGEABLE_KINDS + WINDOW_KINDS + STATE_KINDS


def paged_cache_init(cfg, batch: int, num_pages: int, page_size: int):
    """Cache tree for paged serving: attention k/v entries become a shared
    page pool (num_pages, page_size, KV, D) instead of per-slot dense
    lanes (batch, max_len, KV, D) — full attention AND sliding-window
    (local_attn) layers alike; the windowed layers' pages are recycled by
    the engine as they slide out of the window, so their live footprint
    is O(window) pages per request. Recurrent kinds (ssm/rglru) keep
    fixed-size per-slot state beside the pool — O(1) per slot, nothing to
    page; the engine allocates the slot at admission and rebuilds the
    state by re-prefill on preemption-resume. The pools are indexed by
    the block tables of repro.runtime.kv_cache.PageAllocator (page 0 =
    scratch); one host-side logical->physical mapping per table kind
    (full / windowed) drives every layer of that kind."""
    kinds = tfm.pattern_for(cfg)
    n_super, tail = tfm.layer_plan(cfg)
    unpageable = [k for k in tuple(kinds) + tuple(tail)
                  if k not in PAGED_SERVABLE_KINDS]
    if unpageable:
        raise ValueError(
            f"paged cache cannot host block kind(s) {unpageable}; "
            f"servable kinds are {PAGED_SERVABLE_KINDS}")
    dt = jnp.dtype(cfg.kv_cache_dtype)
    kv, hd = cfg.kv_heads, cfg.resolved_head_dim

    def entry(kind):
        from repro.models import griffin, ssm
        if kind in PAGEABLE_KINDS + WINDOW_KINDS:
            return {"k": jnp.zeros((num_pages, page_size, kv, hd), dt),
                    "v": jnp.zeros((num_pages, page_size, kv, hd), dt)}
        if kind == "ssm":
            return ssm.ssm_cache_init(cfg, batch)
        return griffin.rglru_cache_init(cfg, batch)

    def stacked(kind):
        return jax.tree.map(
            lambda a: jnp.zeros((n_super,) + a.shape, a.dtype), entry(kind))

    scan = {str(j): stacked(k)
            for j, k in enumerate(kinds)} if n_super else {}
    return {"scan": scan, "tail": [entry(k) for k in tail]}


def state_slot_export(cfg, cache, slot):
    """Serialize one slot's recurrent state (every ssm/rglru layer of a
    paged cache tree) into a detached tree — the swap-out half of the
    host-tier protocol (runtime/host_tier.py): a preempted hybrid request
    carries its state to host RAM instead of rebuilding it by re-prefill.
    Non-state entries are omitted (tail is dict-keyed by entry index so
    the import can realign). ``slot`` may be traced."""
    kinds = tfm.pattern_for(cfg)
    _, tail = tfm.layer_plan(cfg)
    state = set(STATE_KINDS)
    return {
        "scan": {str(j): jax.tree.map(lambda le: le[:, slot],
                                      cache["scan"][str(j)])
                 for j, kd in enumerate(kinds)
                 if kd in state and str(j) in cache["scan"]},
        "tail": {str(i): jax.tree.map(lambda le: le[slot], e)
                 for i, (e, kd) in enumerate(zip(cache["tail"], tail))
                 if kd in state},
    }


def state_slot_import(cfg, cache, slot, state_tree):
    """Restore a ``state_slot_export`` tree into ``slot`` of a paged
    cache — the swap-in half. Dtypes are cast back to each entry's
    storage dtype; non-state entries pass through untouched."""
    kinds = tfm.pattern_for(cfg)
    _, tail = tfm.layer_plan(cfg)
    state = set(STATE_KINDS)

    def w_scan(le, s):              # (L, slots, ..) <- (L, ..)
        return le.at[:, slot].set(s.astype(le.dtype))

    def w_tail(le, s):              # (slots, ..) <- (..)
        return le.at[slot].set(s.astype(le.dtype))

    new_scan = {}
    for j, kd in enumerate(kinds):
        e = cache["scan"].get(str(j))
        if e is None:
            continue
        new_scan[str(j)] = jax.tree.map(w_scan, e,
                                        state_tree["scan"][str(j)]) \
            if kd in state else e
    new_tail = [jax.tree.map(w_tail, e, state_tree["tail"][str(i)])
                if kd in state else e
                for i, (e, kd) in enumerate(zip(cache["tail"], tail))]
    return {"scan": new_scan, "tail": new_tail}


def paged_cache_axes(cfg):
    """Logical axes tree matching paged_cache_init structure — the paged
    analogue of cache_axes, used by the tensor-parallel serving plan
    (parallel/tp.py) to shard the page pools over KV heads.

    Page-pool k/v leaves are (num_pages, page_size, KV, D): dim 2 is the
    shard axis (",,kv_heads"); block tables and lengths never appear here
    (they are engine-side and replicated). Recurrent state slots are
    deliberately replicated ("" — NOT cache_axes' "batch,heads"): their
    mixer params stay replicated under the TP plan, so the state must
    match, and at O(slots) scalars per layer there is nothing worth
    sharding."""
    shapes = jax.eval_shape(
        functools.partial(paged_cache_init, cfg, 1, 2, 2))

    def ax(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", "")))
                 for k in path]
        leafname = names[-1] if names else ""
        base = ",,kv_heads" if leafname in ("k", "v") else ""
        if "scan" in names:
            base = ("layers," + base) if base else "layers"
        return base

    return jax.tree_util.tree_map_with_path(ax, shapes)


def cache_shapes(cfg, batch: int, seq_len: int):
    return jax.eval_shape(functools.partial(cache_init, cfg, batch, seq_len))


def cache_axes(cfg):
    """Logical axes tree matching cache_init structure."""
    shapes = cache_shapes(cfg, 1, 2)

    def ax(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        leafname = str(names[-1]) if names else ""
        if leafname in ("k", "v", "xk", "xv"):
            base = "batch,seq,kv_heads"
        elif leafname == "ssm":
            base = "batch,heads"
        elif leafname == "conv":
            base = "batch"
        elif leafname == "h":
            base = "batch,ffn"
        else:
            base = "batch"
        if "scan" in [str(n) for n in names]:
            base = "layers," + base
        return base

    return jax.tree_util.tree_map_with_path(ax, shapes)
