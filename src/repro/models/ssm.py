"""Mamba2 (SSD — state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6): the
sequence is split into chunks; intra-chunk terms are batched GeMMs (exactly
the balanced 3D-tile case Voltra's GeMM core targets — see DESIGN.md
§Arch-applicability) and the inter-chunk recurrence is a short scan over
chunk states. Decode is the O(1) recurrent step.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import Maker, norm_apply, norm_init
from repro.parallel.sharding import NO_RULES, Rules


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.num_groups * s.state_dim
    return d_inner, nheads, conv_dim


def ssm_init(mk: Maker, cfg) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = dims(cfg)
    in_dim = 2 * d_inner + 2 * s.num_groups * s.state_dim + nheads
    return {
        "in_proj": mk((d, in_dim), "wembed,wff", scale=d ** -0.5),
        "conv_w": mk((s.conv_width, conv_dim), "", scale=s.conv_width ** -0.5),
        "conv_b": mk((conv_dim,), "", zeros=True),
        "A_log": mk((nheads,), "heads", ones=True, dtype=jnp.float32),
        "D": mk((nheads,), "heads", ones=True, dtype=jnp.float32),
        "dt_bias": mk((nheads,), "heads", zeros=True, dtype=jnp.float32),
        "norm": norm_init(mk, d_inner, "rmsnorm"),
        "out_proj": mk((d_inner, d), "wff,wembed", scale=d_inner ** -0.5),
    }


def _split(cfg, zxbcdt):
    s = cfg.ssm
    d_inner, nheads, _ = dims(cfg)
    gn = s.num_groups * s.state_dim
    z, xBC, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * gn], axis=-1)
    return z, xBC, dt  # dt: (..., nheads)


def _conv(cfg, p, xBC):
    """Causal depthwise conv over sequence axis 1."""
    w = cfg.ssm.conv_width
    out = p["conv_b"] * jnp.ones_like(xBC)
    for i in range(w):
        shift = w - 1 - i
        xs = jnp.pad(xBC, ((0, 0), (shift, 0), (0, 0)))[:, : xBC.shape[1]]
        out = out + p["conv_w"][i] * xs
    return jax.nn.silu(out)


def _ssd_scan(x, dt, A, B, C, chunk: int):
    """Chunked SSD. x:(b,l,h,p) dt:(b,l,h) A:(h,) B,C:(b,l,g,n).
    Returns (y:(b,l,h,p), final_state:(b,h,p,n))."""
    b, l, h, pdim = x.shape
    g, n = B.shape[2], B.shape[3]
    Q = min(chunk, l)
    pad = (-l) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (l + pad) // Q
    xc = x.reshape(b, nc, Q, h, pdim).transpose(1, 0, 2, 3, 4)       # (nc,b,Q,h,p)
    dtc = dt.reshape(b, nc, Q, h).transpose(1, 0, 3, 2)               # (nc,b,h,Q)
    Bc = B.reshape(b, nc, Q, g, n).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(b, nc, Q, g, n).transpose(1, 0, 2, 3, 4)
    rep = h // g

    def step(S, inp):
        xq, dtq, Bq, Cq = inp                 # (b,Q,h,p) (b,h,Q) (b,Q,g,n) x2
        dA = dtq * A[None, :, None]           # (b,h,Q)
        cum = jnp.cumsum(dA, -1)
        # intra-chunk (diagonal) term
        seg = cum[..., :, None] - cum[..., None, :]                   # (b,h,Q,Q)
        Lmask = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), jnp.exp(seg), 0.0)
        CB = jnp.einsum("bqgn,bkgn->bgqk", Cq, Bq,
                        preferred_element_type=jnp.float32)
        CBh = jnp.repeat(CB, rep, axis=1)                             # (b,h,Q,Q)
        scores = CBh * Lmask * dtq[:, :, None, :]
        y = jnp.einsum("bhqk,bkhp->bqhp", scores.astype(xq.dtype), xq,
                       preferred_element_type=jnp.float32)
        # inter-chunk contribution from entering state S: (b,h,p,n)
        Ch = jnp.repeat(Cq, rep, axis=2)                              # (b,Q,h,n)
        y = y + jnp.einsum("bqhn,bhpn->bqhp", Ch, S,
                           preferred_element_type=jnp.float32) * jnp.exp(
            cum).transpose(0, 2, 1)[..., None]
        # chunk state update
        decay_end = jnp.exp(cum[..., -1:] - cum)                      # (b,h,Q)
        Bh = jnp.repeat(Bq, rep, axis=2)                              # (b,Q,h,n)
        dstate = jnp.einsum("bqhn,bhq,bqhp->bhpn", Bh, decay_end * dtq,
                            xq, preferred_element_type=jnp.float32)
        S_next = jnp.exp(cum[..., -1])[..., None, None] * S + dstate
        return S_next, y.astype(xq.dtype)

    S0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    S_final, ys = jax.lax.scan(step, S0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * Q, h, pdim)[:, :l]
    return y, S_final


def ssm_apply(cfg, p, x, *, rules: Rules = NO_RULES,
              return_state: bool = False, length=None):
    """Full-sequence Mamba2 mixer. x: (B, S, d).

    ``length`` (scalar or (B,), may be traced): number of REAL tokens when
    ``x`` is right-padded to a bucket size (paged bucketed prefill).
    Padded positions get dt = 0, which makes their state update the
    identity (decay exp(dt*A) = 1, injection dt*B*x = 0), so the returned
    final state is exactly the state at position length - 1; the conv
    state gathers the last real rows. Real-position outputs are untouched
    (the SSD scan and conv are causal)."""
    s = cfg.ssm
    d_inner, nheads, conv_dim = dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt = _split(cfg, zxbcdt)
    xBC = _conv(cfg, p, xBC)
    gn = s.num_groups * s.state_dim
    xin, B_, C_ = jnp.split(xBC, [d_inner, d_inner + gn], axis=-1)
    b, l = x.shape[0], x.shape[1]
    xin = xin.reshape(b, l, nheads, s.head_dim)
    xin = rules.cons(xin, "batch,seq,heads")
    B_ = B_.reshape(b, l, s.num_groups, s.state_dim)
    C_ = C_.reshape(b, l, s.num_groups, s.state_dim)
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if length is not None:
        lv = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))
        live = (jnp.arange(l)[None, :] < lv[:, None])[..., None]
        dt_ = jnp.where(live, dt_, 0.0)
    A = -jnp.exp(p["A_log"])
    y, S_final = _ssd_scan(xin, dt_, A, B_, C_, s.chunk)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xin
    y = y.reshape(b, l, d_inner)
    y = norm_apply(p["norm"], y, "rmsnorm") * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    out = rules.cons(out, "batch,seq,embed")
    if return_state:
        w = cfg.ssm.conv_width
        # conv state: last (w-1) *pre-activation* xBC inputs
        zxb = jnp.einsum("bsd,de->bse", x, p["in_proj"])
        _, xBC_raw, _ = _split(cfg, zxb)
        if length is not None:
            from repro.models.griffin import _gather_conv_state
            conv_state = _gather_conv_state(xBC_raw, length, w, l)
        else:
            conv_state = xBC_raw[:, -(w - 1):]
            pad = (w - 1) - conv_state.shape[1]
            if pad > 0:
                conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
        return out, {"ssm": S_final.astype(jnp.float32),
                     "conv": conv_state.astype(x.dtype)}
    return out


def ssm_cache_init(cfg, batch: int):
    s = cfg.ssm
    d_inner, nheads, conv_dim = dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nheads, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim),
                          jnp.dtype(cfg.dtype)),
    }


def _ssm_token_step(cfg, p, carry, zxbcdt):
    """One recurrent token: (S, conv) x (B, in_dim) -> (S', conv', y)."""
    s = cfg.ssm
    d_inner, nheads, _ = dims(cfg)
    S_prev, conv_prev = carry
    z, xBC, dt = _split(cfg, zxbcdt)
    hist = jnp.concatenate([conv_prev, xBC[:, None]], 1)      # (B, w, conv)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, p["conv_w"])
                           + p["conv_b"])
    new_conv = hist[:, 1:]
    gn = s.num_groups * s.state_dim
    xin, B_, C_ = jnp.split(conv_out, [d_inner, d_inner + gn], axis=-1)
    bsz = zxbcdt.shape[0]
    xin = xin.reshape(bsz, nheads, s.head_dim)
    B_ = B_.reshape(bsz, s.num_groups, s.state_dim)
    C_ = C_.reshape(bsz, s.num_groups, s.state_dim)
    rep = nheads // s.num_groups
    Bh = jnp.repeat(B_, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C_, rep, axis=1).astype(jnp.float32)
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B, h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt_ * A)                                            # (B, h)
    xf = xin.astype(jnp.float32)
    S = dA[..., None, None] * S_prev + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt_, Bh, xf)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, S) + p["D"][None, :, None] * xf
    y = y.reshape(bsz, d_inner).astype(zxbcdt.dtype)
    y = norm_apply(p["norm"], y, "rmsnorm") * jax.nn.silu(z)
    return (S, new_conv), y


def ssm_decode(cfg, p, x, cache, *, rules: Rules = NO_RULES):
    """Recurrent decode step. x: (B, T, d) — T == 1 is the plain
    one-token step with plain state shapes. T > 1 (a speculative verify
    block) runs T token steps and returns CHECKPOINTED states — every
    leaf gains a T axis at position 1 ({"ssm": (B, T, h, p, n), "conv":
    (B, T, w-1, conv)}), state t being the state AFTER block row t — so
    the serving engine can roll back to any accepted prefix with one
    gather (the recurrent analogue of PageAllocator.truncate_to)."""
    T = x.shape[1]
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    if T == 1:
        (S, new_conv), y = _ssm_token_step(
            cfg, p, (cache["ssm"], cache["conv"]), zxbcdt[:, 0])
        out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None]
        return (rules.cons(out, "batch,seq,embed"),
                {"ssm": S, "conv": new_conv})

    def step(carry, zx_t):
        carry2, y = _ssm_token_step(cfg, p, carry, zx_t)
        return carry2, (carry2[0], carry2[1], y)

    _, (Ss, convs, ys) = jax.lax.scan(step, (cache["ssm"], cache["conv"]),
                                      zxbcdt.transpose(1, 0, 2))
    out = jnp.einsum("tbe,ed->btd", ys, p["out_proj"])
    return (rules.cons(out, "batch,seq,embed"),
            {"ssm": Ss.transpose(1, 0, 2, 3, 4),
             "conv": convs.transpose(1, 0, 2, 3)})
