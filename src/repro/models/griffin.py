"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

Training/prefill runs the diagonal linear recurrence with an associative
scan; decode is the O(1) step. Local attention blocks of the hybrid pattern
live in ``layers.attention_*`` with a ring-buffer window cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.layers import Maker
from repro.parallel.sharding import NO_RULES, Rules

_C = 8.0  # RG-LRU constant


def lru_dim(cfg) -> int:
    return cfg.hybrid.lru_dim or cfg.d_model


def rglru_init(mk: Maker, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    r = lru_dim(cfg)
    w = 4  # conv width (temporal conv, RecurrentGemma uses 4)
    return {
        "proj_x": mk((d, r), "wembed,wff", scale=d ** -0.5),
        "proj_y": mk((d, r), "wembed,wff", scale=d ** -0.5),
        "conv_w": mk((w, r), "", scale=w ** -0.5),
        "conv_b": mk((r,), "", zeros=True),
        "gate_a": mk((r, r), "wff,", scale=r ** -0.5),
        "gate_a_b": mk((r,), "", zeros=True),
        "gate_x": mk((r, r), "wff,", scale=r ** -0.5),
        "gate_x_b": mk((r,), "", zeros=True),
        # Lambda init so that a ~ U(0.9, 0.999)-ish at r=0.5 (paper init)
        "lam": mk((r,), "ffn", ones=True, dtype=jnp.float32),
        "proj_out": mk((r, d), "wff,wembed", scale=r ** -0.5),
    }


def _conv(p, x):
    """Causal depthwise conv, width 4, over axis 1."""
    w = p["conv_w"].shape[0]
    out = p["conv_b"] * jnp.ones_like(x)
    for i in range(w):
        shift = w - 1 - i
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + p["conv_w"][i] * xs
    return out


def _gates(p, u):
    """u: (..., r) post-conv branch input -> (a, gated_input) fp32."""
    uf = u.astype(jnp.float32)
    r_t = jax.nn.sigmoid(uf @ p["gate_a"].astype(jnp.float32) + p["gate_a_b"])
    i_t = jax.nn.sigmoid(uf @ p["gate_x"].astype(jnp.float32) + p["gate_x_b"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r_t
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i_t * uf)
    return a, gated


def _gather_conv_state(conv_in, length, w, S):
    """Last ``w - 1`` REAL rows of a right-padded (B, S, r) input, at a
    traced per-batch ``length`` — rows before position 0 are zero, exactly
    the static path's left-pad."""
    B = conv_in.shape[0]
    lv = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    idx = lv[:, None] - (w - 1) + jnp.arange(w - 1)[None, :]   # (B, w-1)
    g = jnp.take_along_axis(conv_in, jnp.clip(idx, 0, S - 1)[..., None],
                            axis=1)
    return jnp.where(idx[..., None] >= 0, g, 0)


def rglru_apply(cfg, p, x, *, rules: Rules = NO_RULES,
                return_state: bool = False, length=None):
    """Full-sequence RG-LRU block. x: (B, S, d).

    ``length`` (scalar or (B,), may be traced): number of REAL tokens when
    ``x`` is right-padded to a bucket size (the paged engine's bucketed
    prefill). Recurrence updates at padded positions are forced to the
    identity (a = 1, b = 0), so the carried state — and therefore the
    returned decode state — is exactly the state at position length - 1;
    the conv state likewise gathers the last real rows. Outputs at real
    positions are untouched (the recurrence and conv are causal)."""
    B, S, _ = x.shape
    u = jnp.einsum("bsd,dr->bsr", x, p["proj_x"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["proj_y"]))
    u = rules.cons(u, "batch,seq,ffn")
    conv_in = u
    u = _conv(p, u)
    a, b = _gates(p, u)
    if length is not None:
        lv = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
        live = (jnp.arange(S)[None, :] < lv[:, None])[..., None]
        a = jnp.where(live, a, 1.0)
        b = jnp.where(live, b, 0.0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = hh.astype(x.dtype) * gate
    out = jnp.einsum("bsr,rd->bsd", h, p["proj_out"])
    out = rules.cons(out, "batch,seq,embed")
    if return_state:
        w = p["conv_w"].shape[0]
        if length is not None:
            conv_state = _gather_conv_state(conv_in, length, w, S)
        else:
            conv_state = conv_in[:, -(w - 1):]
            pad = (w - 1) - conv_state.shape[1]
            if pad > 0:
                conv_state = jnp.pad(conv_state, ((0, 0), (pad, 0), (0, 0)))
        return out, {"h": hh[:, -1].astype(jnp.float32),
                     "conv": conv_state.astype(x.dtype)}
    return out


def rglru_cache_init(cfg, batch: int):
    r = lru_dim(cfg)
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, 3, r), jnp.dtype(cfg.dtype))}


def rglru_decode(cfg, p, x, cache, *, rules: Rules = NO_RULES):
    """Decode step. x: (B, T, d) — T == 1 is the plain one-token step and
    returns plain state shapes. T > 1 (a speculative verify block) runs a
    T-step recurrence and returns CHECKPOINTED states — every leaf gains
    a T axis at position 1 ({"h": (B, T, r), "conv": (B, T, w-1, r)}),
    state t being the state AFTER absorbing block row t — so the serving
    engine can roll back to any accepted prefix with one gather
    (PagedServingEngine._select_fn; the recurrent analogue of
    PageAllocator.truncate_to)."""
    T = x.shape[1]
    u_all = jnp.einsum("bsd,dr->bsr", x, p["proj_x"])
    gate_all = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["proj_y"]))
    if T == 1:
        u, gate = u_all[:, 0], gate_all[:, 0]
        hist = jnp.concatenate([cache["conv"], u[:, None]], 1)    # (B, w, r)
        conv_out = jnp.einsum("bwr,wr->br", hist, p["conv_w"]) + p["conv_b"]
        a, b = _gates(p, conv_out)
        h_new = a * cache["h"] + b
        h = h_new.astype(x.dtype) * gate
        out = jnp.einsum("br,rd->bd", h, p["proj_out"])[:, None]
        out = rules.cons(out, "batch,seq,embed")
        return out, {"h": h_new, "conv": hist[:, 1:]}

    def step(carry, u_t):
        h_prev, conv_prev = carry
        hist = jnp.concatenate([conv_prev, u_t[:, None]], 1)      # (B, w, r)
        conv_out = jnp.einsum("bwr,wr->br", hist, p["conv_w"]) + p["conv_b"]
        a, b = _gates(p, conv_out)
        h_new = a * h_prev + b
        conv_new = hist[:, 1:]
        return (h_new, conv_new), (h_new, conv_new)

    _, (hs, convs) = jax.lax.scan(step, (cache["h"], cache["conv"]),
                                  u_all.transpose(1, 0, 2))
    h_seq = hs.transpose(1, 0, 2)                                 # (B, T, r)
    h = h_seq.astype(x.dtype) * gate_all
    out = jnp.einsum("btr,rd->btd", h, p["proj_out"])
    out = rules.cons(out, "batch,seq,embed")
    return out, {"h": h_seq, "conv": convs.transpose(1, 0, 2, 3)}
