"""Layer library: norms, rotary, GQA attention (full/windowed/cross/decode),
chunked flash attention (pure-jnp online softmax), gated MLP and dropping MoE.

All layers are functional: ``<layer>_init(mk, cfg, ...) -> params`` and
``<layer>_apply(cfg, params, ...) -> out``. ``mk`` is a ``Maker`` that either
initializes arrays or records logical sharding axes (same code path for both,
so the axes tree always matches the params tree).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.sharding import NO_RULES, Rules

# ---------------------------------------------------------------------------
# Param maker
# ---------------------------------------------------------------------------


class Maker:
    """mode='init' -> arrays; mode='axes' -> logical-axes strings (leaves)."""

    def __init__(self, mode: str, key=None, dtype=jnp.bfloat16):
        assert mode in ("init", "axes")
        self.mode = mode
        self.key = key
        self.dtype = dtype
        self._n = 0

    def __call__(self, shape, axes: str = "", scale: Optional[float] = None,
                 zeros: bool = False, ones: bool = False, dtype=None):
        if self.mode == "axes":
            return axes
        dt = dtype or self.dtype
        if ones:
            return jnp.ones(shape, dt)
        if zeros:
            return jnp.zeros(shape, dt)
        self._n += 1
        k = jax.random.fold_in(self.key, self._n)
        sc = scale if scale is not None else (shape[0] ** -0.5 if shape else 1.0)
        return (jax.random.normal(k, shape, jnp.float32) * sc).astype(dt)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(mk: Maker, d: int, kind: str) -> Dict[str, Any]:
    p = {"scale": mk((d,), "", ones=True, dtype=jnp.float32)}
    if kind == "layernorm":
        p["bias"] = mk((d,), "", zeros=True, dtype=jnp.float32)
    return p


def norm_apply(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embedding
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    ang = ang[..., None, :]                                  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (pure jnp; online softmax; bounded memory).
# The PDMA/VMEM-residency analogue at HLO level: per-(q,kv)-block partials
# only, never the full (S, S) score matrix.
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_valid: Optional[int] = None,
                    q_chunk: int = 256, kv_chunk: int = 512,
                    chunked: bool = True):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D). GQA via head grouping.

    window > 0 -> sliding-window causal attention.
    kv_valid   -> only first `kv_valid` kv positions are real (static or traced).
    q_offset   -> absolute position of q[0] (scalar or (B,) traced).
    chunked=False -> one-shot softmax (no scan): the right path under
    sequence/context parallelism, where the per-device q block is already
    small — the chunk scan would otherwise materialize its (qc, kc)
    intermediates at every fusion boundary x trip count (the 36 TiB/step
    pathology of EXPERIMENTS.md §Perf A4).
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = D ** -0.5
    if not chunked:
        qg = q.reshape(B, Sq, KV, G, D)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                       preferred_element_type=jnp.float32) * scale
        kpos = jnp.arange(Sk)
        kv_lim = Sk if kv_valid is None else kv_valid
        mask = kpos[None, :] < kv_lim
        if causal:
            qpos = jnp.arange(Sq) + (
                q_offset if jnp.ndim(q_offset) == 0 else q_offset[:, None])
            cm = qpos[..., :, None] >= kpos[None, :]
            if window:
                cm &= qpos[..., :, None] - kpos[None, :] < window
            mask = mask & cm
        if mask.ndim == 2:
            mask = mask[None]
        s = jnp.where(mask[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, Sq, H, D).astype(q.dtype)
    qc, kc = min(q_chunk, Sq), min(kv_chunk, Sk)
    # pad to chunk multiples
    pq = (-Sq) % qc
    pk = (-Sk) % kc
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    nq, nk = (Sq + pq) // qc, (Sk + pk) // kc
    qp = qp.reshape(B, nq, qc, KV, G, D)
    kp = kp.reshape(B, nk, kc, KV, D)
    vp = vp.reshape(B, nk, kc, KV, D)
    kv_lim = Sk if kv_valid is None else kv_valid

    def q_block(carry, qi):
        qb = qp[:, qi]  # (B, qc, KV, G, D)
        qpos = qi * qc + jnp.arange(qc) + (
            q_offset if jnp.ndim(q_offset) == 0 else q_offset[:, None])

        def kv_block(acc, ki):
            m, l, o = acc
            kb, vb = kp[:, ki], vp[:, ki]
            kpos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = (kpos[None, :] < kv_lim)
            if causal:
                cm = qpos[..., :, None] >= kpos[None, :]
                if window:
                    cm &= qpos[..., :, None] - kpos[None, :] < window
                mask = mask & cm
            # mask: (qc, kc) or (B, qc, kc) -> broadcast over (b, k, g, q, c)
            if mask.ndim == 2:
                mask = mask[None]
            s = jnp.where(mask[:, None, None], s, -1e30)
            m2 = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m2[..., None])
            corr = jnp.exp(m - m2)
            l2 = l * corr + p.sum(-1)
            o2 = o * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m2, l2, o2), None

        init = (jnp.full((B, KV, G, qc), -jnp.inf, jnp.float32),
                jnp.zeros((B, KV, G, qc), jnp.float32),
                jnp.zeros((B, KV, G, qc, D), jnp.float32))
        (m, l, o), _ = jax.lax.scan(kv_block, init, jnp.arange(nk))
        o = o / jnp.maximum(l[..., None], 1e-30)
        return carry, o.transpose(0, 3, 1, 2, 4)  # (B, qc, KV, G, D)

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, H, D)
    return out[:, :Sq].astype(q.dtype)


def attend_decode(q, ck, cv, pos, *, window: int = 0, ring: bool = False,
                  kv_chunk: int = 0):
    """Decode attention vs a cache. q: (B, Tq, H, D) — Tq == 1 for plain
    decode, Tq > 1 for a speculative multi-token query block; ck/cv:
    (B, S, KV, D); pos: (B,) absolute position of the FIRST new token
    (row t sits at pos + t; the cache holds every earlier token plus the
    block itself, so row t attends to pos + t + 1 keys — in-block causal).

    window > 0 bounds each row to the last ``window`` keys. Two layouts:

    * ``ring=False`` (paged / contiguous): buffer index == absolute key
      position, so the window is a per-row position band
      ``(pos + t - window, pos + t]`` — works for any Tq (the paged
      sliding-window oracle, matching ops.paged_attention's masking).
    * ``ring=True``: ``ck``/``cv`` is a window-sized ring buffer (slot =
      position % S) that only ever HOLDS the last S positions, so
      masking is by valid-slot count. Single-token by construction: a
      Tq > 1 block's older rows would need positions the ring has
      already overwritten — rejected with a ValueError (surfaced with
      the layer kind at api.decode_step; see ISSUE 5 satellite).

    Chunked over the cache length with an online softmax so the (B, KV, G,
    Tq, S) score tensor is never materialized — for a 32k cache this is
    the difference between streaming the cache once and ~6 fp32 passes
    over a 17 GB intermediate (EXPERIMENTS.md §Perf C3)."""
    B, Tq, H, D = q.shape
    _, S, KV, _ = ck.shape
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, D)
    if ring:
        if Tq != 1:
            raise ValueError(
                f"ring-buffer windowed decode is single-token (got a "
                f"Tq={Tq} query block): the ring has already overwritten "
                f"positions the block's older rows would attend to — use "
                f"the paged window layout (ring=False) for multi-token "
                f"blocks")
        nvalid = jnp.minimum(pos + 1, S)[:, None]  # ring buffer: slot count
    else:
        nvalid = pos[:, None] + jnp.arange(Tq)[None, :] + 1    # (B, Tq)
    c = S if kv_chunk <= 0 else min(kv_chunk, S)
    if S % c:
        c = S  # ragged cache lengths: single chunk (small-cache tests)
    nc = S // c
    ckc = ck.reshape(B, nc, c, KV, D)
    cvc = cv.reshape(B, nc, c, KV, D)

    def chunk(acc, i):
        m, l, o = acc
        kb = ckc[:, i]
        vb = cvc[:, i]
        s = jnp.einsum("btkgd,bckd->bkgtc", qg, kb,
                       preferred_element_type=jnp.float32) * (D ** -0.5)
        slots = i * c + jnp.arange(c)
        mask = slots[None, None, :] < nvalid[:, :, None]       # (B, Tq, c)
        if window and not ring:
            # buffer index == absolute position: drop keys older than the
            # row's window (the ring layout never holds them to begin with)
            mask = mask & (slots[None, None, :]
                           > nvalid[:, :, None] - 1 - window)
        mask = mask[:, None, None]                  # over (b, k, g, t, c)
        m2 = jnp.maximum(m, jnp.where(mask, s, -jnp.inf).max(-1))
        m2 = jnp.maximum(m2, -1e30)       # fully-masked chunk guard
        p = jnp.where(mask, jnp.exp(s - m2[..., None]), 0.0)
        corr = jnp.exp(m - m2)
        l2 = l * corr + p.sum(-1)
        o2 = o * corr[..., None] + jnp.einsum(
            "bkgtc,bckd->bkgtd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m2, l2, o2), None

    init = (jnp.full((B, KV, G, Tq), -1e30, jnp.float32),
            jnp.zeros((B, KV, G, Tq), jnp.float32),
            jnp.zeros((B, KV, G, Tq, D), jnp.float32))
    if nc == 1:
        (m, l, o), _ = chunk(init, 0)
    else:
        (m, l, o), _ = jax.lax.scan(chunk, init, jnp.arange(nc))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV-cache quantization (opt-in int8 storage; the chip's INT8 theme applied
# to the decode cache — halves cache footprint and read traffic)
# ---------------------------------------------------------------------------


def kv_quant(cfg, x):
    """bf16 k/v -> cache storage dtype (symmetric, static absmax bound)."""
    if cfg.kv_cache_dtype != "int8":
        return x.astype(jnp.dtype(cfg.kv_cache_dtype))
    q = jnp.round(x.astype(jnp.float32) * (127.0 / cfg.kv_scale))
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def kv_dequant(cfg, x, dtype):
    if x.dtype != jnp.int8:
        return x.astype(dtype)
    return (x.astype(jnp.float32) * (cfg.kv_scale / 127.0)).astype(dtype)


# ---------------------------------------------------------------------------
# Attention layer (GQA, optional bias / window / cross)
# ---------------------------------------------------------------------------


def attention_init(mk: Maker, cfg) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.resolved_head_dim
    p = {
        "wq": mk((d, h, hd), "wembed,wheads", scale=d ** -0.5),
        "wk": mk((d, kv, hd), "wembed,wkv_heads", scale=d ** -0.5),
        "wv": mk((d, kv, hd), "wembed,wkv_heads", scale=d ** -0.5),
        "wo": mk((h, hd, d), "wheads,,wembed", scale=(h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = mk((h, hd), "wheads", zeros=True)
        p["bk"] = mk((kv, hd), "wkv_heads", zeros=True)
        p["bv"] = mk((kv, hd), "wkv_heads", zeros=True)
    return p


def _qkv(cfg, p, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", kv_x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def attention_apply(cfg, p, x, *, rules: Rules = NO_RULES, positions=None,
                    window: int = 0, cross_kv=None, causal: bool = True,
                    prefix=None):
    """Full-sequence attention (train/prefill). Returns (out, kv) so callers
    can build caches. cross_kv=(k,v) for encoder-decoder cross attention.

    prefix=(pk, pv, plen): suffix-only prefill against a reused KV prefix
    (prefix sharing, runtime/prefix_cache.py). ``x`` holds only the tokens
    AFTER the shared prefix; pk/pv (B, Pb, KV, D, cache storage dtype) are
    the prefix KV gathered from the paged pool, valid for the first
    ``plen`` (traced) rows; ``positions`` must already be offset by plen.
    The suffix k/v are spliced in at row plen, so buffer index == absolute
    position for every real token and plain causal masking handles both
    the prefix-buffer tail and the suffix bucket padding (garbage rows sit
    at positions > every real query). Returned kv is the SUFFIX-only k/v —
    exactly what the caller must scatter into its pages."""
    B, S, _ = x.shape
    if cross_kv is not None:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        k, v = cross_kv
        q = rules.cons(q, "batch,seq,heads")
        out = flash_attention(q, k, v, causal=False)
        kv = None
    else:
        q, k, v = _qkv(cfg, p, x)
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        q = rules.cons(q, "batch,seq,heads")
        k = rules.cons(k, "batch,seq,kv_heads")
        if prefix is not None:
            pk, pv, plen = prefix
            plen = jnp.asarray(plen, jnp.int32)
            start = (jnp.int32(0), plen, jnp.int32(0), jnp.int32(0))
            kf = jnp.concatenate([kv_dequant(cfg, pk, x.dtype),
                                  jnp.zeros_like(k)], axis=1)
            vf = jnp.concatenate([kv_dequant(cfg, pv, x.dtype),
                                  jnp.zeros_like(v)], axis=1)
            kf = jax.lax.dynamic_update_slice(kf, k, start)
            vf = jax.lax.dynamic_update_slice(vf, v, start)
            out = flash_attention(q, kf, vf, causal=causal, window=window,
                                  q_offset=plen,
                                  chunked=cfg.flash_chunking)
        else:
            out = flash_attention(q, k, v, causal=causal, window=window,
                                  chunked=cfg.flash_chunking)
        kv = (k, v)
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    # contraction over heads: under manual TP (ManualRules inside a
    # shard_map body) each shard holds H/M heads and this is the psum
    # point; identity everywhere else
    out = rules.contract(out, "heads")
    return rules.cons(out, "batch,seq,embed"), kv


def attention_decode(cfg, p, x, cache, pos, *, rules: Rules = NO_RULES,
                     window: int = 0, cross: bool = False,
                     block_table=None):
    """Decode step. x: (B, T, d) — T == 1 for plain decode; pos: (B,)
    position of the FIRST new token. Returns (out, new_cache).

    Dense mode (block_table=None): cache {"k","v"}: (B, S, KV, D), one lane
    per batch slot; single-token only (T == 1). window > 0 means the lane
    is a window-sized ring buffer (slot = pos % S).
    Paged mode: cache {"k","v"}: (P, page, KV, D) — a shared page pool —
    and block_table: (B, n_blocks) int32 mapping each request's logical
    blocks to physical pages (repro.runtime.kv_cache). The T new tokens
    are scattered token-granularly into their owner's pages (a block may
    straddle a page boundary; rows past the table's capacity land on the
    scratch page — their logits are only ever produced to be discarded by
    the engine's max_len stop); attention then runs the block-table
    indirection INSIDE the flash-decode kernel (ops.paged_attention), one
    page tile at a time, causally masked row-by-row against pos + T — so
    pool garbage (scratch page, not-yet-written tail) never contributes
    probability mass and the dense (B, n_blocks*page, KV, D) gathered KV
    never materializes. T > 1 is the speculative-verify block (engine
    spec_k): K drafted tokens + the current one score in ONE page sweep.
    window > 0 in paged mode is a sliding-window layer (hybrid
    local_attn) on the paged layout: logical block index still means
    absolute position, the kernel masks each row to its last `window`
    keys and skips pages entirely below the window — the ones the engine
    recycles to scratch (runtime/kv_cache.release_prefix) — so the layer
    holds O(window) live pages however long the request runs.
    cfg.paged_attn_impl == "gather" keeps the PR-1 dense-gather path as
    the measured baseline (benchmarks/serve_bench.py)."""
    if cross:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        ck = kv_dequant(cfg, cache["k"], x.dtype)
        cv = kv_dequant(cfg, cache["v"], x.dtype)
        n = jnp.full((x.shape[0],), ck.shape[1], jnp.int32)
        out = attend_decode(q, ck, cv, n - 1)
        new_cache = cache
    elif block_table is not None:
        B, T = x.shape[0], x.shape[1]
        q, k, v = _qkv(cfg, p, x)
        pos_t = pos[:, None] + jnp.arange(T)[None, :]        # (B, T)
        q = rope(q, pos_t, cfg.rope_theta)
        k = rope(k, pos_t, cfg.rope_theta)
        page = cache["k"].shape[1]
        n_blk = block_table.shape[1]
        # physical destination of row t: page block_table[b, (pos+t)//page],
        # row (pos+t)%page — token-granular, so a T-block may straddle a
        # page boundary. Dead slots carry an all-scratch table, and rows
        # past the table's capacity (a verify block overrunning max_len —
        # their logits are discarded by the engine's max_len stop) are
        # redirected to the scratch page (id 0) too, so neither can ever
        # scribble over a live lane.
        blk = pos_t // page
        phys = jnp.where(
            blk < n_blk,
            jnp.take_along_axis(block_table,
                                jnp.minimum(blk, n_blk - 1), axis=1),
            0)
        off = pos_t % page
        ck = cache["k"].at[phys, off].set(kv_quant(cfg, k))
        cv = cache["v"].at[phys, off].set(kv_quant(cfg, v))
        if cfg.paged_attn_impl == "gather":
            # PR-1 baseline: dense per-layer pool gather (the "separated
            # memory" anti-pattern; kept only for serve_bench comparison).
            # Windowed layers mask by absolute position band (ring=False:
            # buffer index == absolute position in this layout).
            kg = ck[block_table].reshape(B, n_blk * page, *ck.shape[2:])
            vg = cv[block_table].reshape(B, n_blk * page, *cv.shape[2:])
            out = attend_decode(q, kv_dequant(cfg, kg, q.dtype),
                                kv_dequant(cfg, vg, q.dtype), pos,
                                window=window,
                                kv_chunk=cfg.decode_kv_chunk)
        else:
            scale = cfg.kv_scale if ck.dtype == jnp.int8 else None
            out = ops.paged_attention(q, ck, cv, block_table,
                                      pos + T, kv_scale=scale,
                                      window=window)
        new_cache = {"k": ck, "v": cv}
    else:
        q, k, v = _qkv(cfg, p, x)
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
        S = cache["k"].shape[1]
        slot = jnp.remainder(pos, S) if window else jnp.minimum(pos, S - 1)
        # one-hot masked write instead of a per-batch dynamic-update-slice:
        # elementwise over the cache, so it partitions cleanly when the
        # cache seq axis is context-parallel sharded (a vmapped DUS at a
        # traced index forces SPMD to re-materialize the whole cache —
        # EXPERIMENTS.md §Perf C3).
        hit = (jnp.arange(S)[None, :] == slot[:, None])[..., None, None]
        ck = jnp.where(hit, kv_quant(cfg, k), cache["k"])
        cv = jnp.where(hit, kv_quant(cfg, v), cache["v"])
        ck = rules.cons(ck, "batch,seq,kv_heads")
        cv = rules.cons(cv, "batch,seq,kv_heads")
        out = attend_decode(q, kv_dequant(cfg, ck, q.dtype),
                            kv_dequant(cfg, cv, q.dtype), pos,
                            window=window, ring=window > 0,
                            kv_chunk=cfg.decode_kv_chunk)
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    out = rules.contract(out, "heads")   # TP psum point (see attention_apply)
    return rules.cons(out, "batch,seq,embed"), new_cache


# ---------------------------------------------------------------------------
# MLP (gated or plain)
# ---------------------------------------------------------------------------


def mlp_init(mk: Maker, cfg, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    p = {"wi": mk((d, f), "wembed,wff", scale=d ** -0.5),
         "wo": mk((f, d), "wff,wembed", scale=f ** -0.5)}
    if cfg.gated_ffn:
        p["wg"] = mk((d, f), "wembed,wff", scale=d ** -0.5)
    return p


def _act(cfg, h):
    return jax.nn.silu(h) if cfg.act == "silu" else jax.nn.gelu(h)


def mlp_apply(cfg, p, x, *, rules: Rules = NO_RULES):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.gated_ffn:
        h = _act(cfg, jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    else:
        h = _act(cfg, h)
    h = rules.cons(h, "batch,seq,ffn")
    out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    # contraction over ffn: the TP psum point when wi/wg/wo are sharded
    # over the model axis (identity otherwise — including MoE configs,
    # whose plan never shards ffn so the shared expert stays correct)
    out = rules.contract(out, "ffn")
    return rules.cons(out, "batch,seq,embed")


# ---------------------------------------------------------------------------
# Dropping MoE (capacity factor; cumsum position assignment; EP over experts)
# ---------------------------------------------------------------------------


def moe_init(mk: Maker, cfg) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    p = {
        "router": mk((d, e), "wembed,wexperts", scale=d ** -0.5, dtype=jnp.float32),
        "wi": mk((e, d, f), "wexperts,wembed,wff", scale=d ** -0.5),
        "wg": mk((e, d, f), "wexperts,wembed,wff", scale=d ** -0.5),
        "wo": mk((e, f, d), "wexperts,wff,wembed", scale=f ** -0.5),
    }
    if cfg.moe.shared_expert:
        p["shared"] = mlp_init(mk, cfg)
    return p


def moe_apply(cfg, p, x, *, rules: Rules = NO_RULES):
    """Token-dropping MoE with GShard-style grouped dispatch.

    Tokens are split into `dispatch_groups` groups; capacity is enforced
    per group and the group dim carries the batch sharding, so the
    routing scatter/gather stay local to their data shard while the
    expert dim is tensor-parallel. Without grouping, SPMD replicates the
    global-capacity buffer and all-reduces it every layer, and every data
    rank runs the full expert GEMM (EXPERIMENTS.md §Perf B3/B5/B6).
    Returns (out, aux_losses)."""
    B, S, d = x.shape
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    T = B * S
    G = m.dispatch_groups if T % max(m.dispatch_groups, 1) == 0 else 1
    Tg = T // G
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, -1)
    gate_w, gate_i = jax.lax.top_k(probs, K)                              # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    C = int(max(4, -(-Tg * m.capacity_factor * K) // E))   # per group
    # priority order within each group: slot-major, matching Switch.
    eidx = gate_i.reshape(G, Tg, K).transpose(0, 2, 1).reshape(G, K * Tg)
    oh = jax.nn.one_hot(eidx, E, dtype=jnp.int32)          # (G, K*Tg, E)
    pos = jnp.cumsum(oh, 1) - 1
    pos = jnp.take_along_axis(pos, eidx[..., None], 2)[..., 0]
    keep = pos < C
    flat = jnp.where(keep, eidx * C + pos, E * C)          # (G, K*Tg)

    xg = xt.reshape(G, Tg, d)
    xrep = (jnp.broadcast_to(xg[:, None], (G, K, Tg, d))
            .reshape(G, K * Tg, d))
    xrep = rules.cons(xrep, "batch,,embed")

    def scatter(fl, xr):
        return jnp.zeros((E * C + 1, d), x.dtype).at[fl].add(xr)

    buf = jax.vmap(scatter)(flat, xrep)                    # (G, E*C+1, d)
    buf = rules.cons(buf[:, : E * C].reshape(G, E, C, d), "batch,experts")

    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"])
    g = jnp.einsum("gecd,edf->gecf", buf, p["wg"])
    h = rules.cons(jax.nn.silu(g) * h, "batch,experts,,ffn")
    eo = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    eo = rules.cons(eo, "batch,experts")

    eo_flat = jnp.concatenate(
        [eo.reshape(G, E * C, d), jnp.zeros((G, 1, d), eo.dtype)], 1)
    got = jnp.take_along_axis(eo_flat, flat[..., None], 1)  # (G, K*Tg, d)
    got = rules.cons(got, "batch,,embed").reshape(G, K, Tg, d)
    w = (gate_w.reshape(G, Tg, K).transpose(0, 2, 1)
         * keep.reshape(G, K, Tg)).astype(x.dtype)
    out = jnp.einsum("gkt,gktd->gtd", w, got).reshape(B, S, d)
    if m.shared_expert:
        out = out + mlp_apply(cfg, p["shared"], x, rules=rules)

    # aux losses: load-balance (Switch) + router z-loss (global)
    me = probs.mean(0)                                   # (E,)
    ce = jnp.zeros((E,)).at[gate_i.reshape(-1)].add(1.0) / (T * K)
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)
    return rules.cons(out, "batch,seq,embed"), {"lb_loss": lb, "z_loss": z}
