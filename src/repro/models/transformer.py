"""Composable decoder (+ optional encoder) built from pattern blocks.

A model is ``embedding -> scan over super-blocks -> tail blocks -> norm ->
head``. A *super-block* is one period of the arch's block pattern (e.g.
RecurrentGemma: ``(rglru, rglru, local_attn)``); homogeneous params of each
pattern position are stacked and scanned (small HLO, production-style).
Layers that don't fit a whole period form an explicitly-applied tail.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import griffin, ssm
from repro.models.layers import (Maker, attention_apply, attention_decode,
                                 attention_init, mlp_apply, mlp_init,
                                 moe_apply, moe_init, norm_apply, norm_init)
from repro.parallel.sharding import NO_RULES, Rules

VOCAB_PAD = 256


def padded_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD) * VOCAB_PAD


# ---------------------------------------------------------------------------
# Block pattern
# ---------------------------------------------------------------------------


def pattern_for(cfg) -> Tuple[str, ...]:
    if cfg.is_encdec:
        return ("dec",)
    if cfg.family == "ssm":
        return ("ssm",)
    if cfg.family == "hybrid":
        return cfg.hybrid.pattern
    if cfg.moe is not None and cfg.moe.num_experts > 0:
        period = cfg.moe.moe_every
        return tuple(["attn_mlp"] * (period - 1) + ["attn_moe"])
    return ("attn_mlp",)


def layer_plan(cfg) -> Tuple[int, Tuple[str, ...]]:
    """(n_super, tail_kinds)."""
    pat = pattern_for(cfg)
    p = len(pat)
    return cfg.num_layers // p, pat[: cfg.num_layers % p]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def block_init(mk: Maker, cfg, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": norm_init(mk, d, cfg.norm)}
    if kind in ("attn_mlp", "attn_moe", "local_attn", "enc", "dec"):
        p["attn"] = attention_init(mk, cfg)
    elif kind == "ssm":
        p["mixer"] = ssm.ssm_init(mk, cfg)
        return p  # pure mamba block: no FFN sublayer
    elif kind == "rglru":
        p["mixer"] = griffin.rglru_init(mk, cfg)
    else:
        raise ValueError(kind)
    if kind == "dec":
        p["lnx"] = norm_init(mk, d, cfg.norm)
        p["cross"] = attention_init(mk, cfg)
    p["ln2"] = norm_init(mk, d, cfg.norm)
    if kind == "attn_moe":
        p["moe"] = moe_init(mk, cfg)
    else:
        p["mlp"] = mlp_init(mk, cfg)
    return p


def _grow(cfg, kv, max_len):
    """Pad a full-attention prefill kv (B, S, KV, D) out to max_len slots
    (stored in the cache dtype — int8 when kv_cache_dtype says so)."""
    from repro.models.layers import kv_quant
    k, v = kv_quant(cfg, kv[0]), kv_quant(cfg, kv[1])
    pad = max(0, (max_len or 0) - k.shape[1])
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k, "v": v}


def block_apply(cfg, kind: str, p, x, *, rules: Rules = NO_RULES,
                positions=None, enc_out=None, want_cache: bool = False,
                max_len=None, prefix=None, length=None,
                paged_kv: bool = False):
    """Full-sequence block. Returns (x, cache_entry, aux). prefix=(pk, pv,
    plen) switches full attention to suffix-only prefill against reused
    prefix KV (layers.attention_apply); the cache entry then holds the
    suffix k/v only.

    length (scalar/(B,), may be traced): REAL token count when x is
    right-padded to a bucket (paged bucketed prefill) — recurrent blocks
    mask their state updates past it so the returned state is the state
    at length - 1 (ssm/rglru_apply). paged_kv=True makes local_attn
    return the FULL-sequence kv like full attention (the paged engine
    scatters only the live window blocks into pages) instead of the
    dense engine's window-sized ring buffer."""
    aux = {}
    cache = None
    h = norm_apply(p["ln1"], x, cfg.norm)
    if kind in ("attn_mlp", "attn_moe", "dec"):
        a, kv = attention_apply(cfg, p["attn"], h, rules=rules,
                                positions=positions, prefix=prefix)
        if want_cache:
            cache = _grow(cfg, kv, max_len)
    elif kind == "local_attn":
        w = cfg.hybrid.window
        a, kv = attention_apply(cfg, p["attn"], h, rules=rules,
                                positions=positions, window=w)
        if want_cache:
            cache = _grow(cfg, kv, None) if paged_kv \
                else _window_cache(cfg, kv, w)
    elif kind == "enc":
        a, _ = attention_apply(cfg, p["attn"], h, rules=rules,
                               positions=positions, causal=False)
    elif kind == "ssm":
        if want_cache:
            a, cache = ssm.ssm_apply(cfg, p["mixer"], h, rules=rules,
                                     return_state=True, length=length)
        else:
            a = ssm.ssm_apply(cfg, p["mixer"], h, rules=rules)
        return x + a, cache, aux
    elif kind == "rglru":
        if want_cache:
            a, cache = griffin.rglru_apply(cfg, p["mixer"], h, rules=rules,
                                           return_state=True, length=length)
        else:
            a = griffin.rglru_apply(cfg, p["mixer"], h, rules=rules)
        x = x + a
        h2 = norm_apply(p["ln2"], x, cfg.norm)
        x = x + mlp_apply(cfg, p["mlp"], h2, rules=rules)
        return x, cache, aux
    else:
        raise ValueError(kind)
    x = x + a
    if kind == "dec":
        hx = norm_apply(p["lnx"], x, cfg.norm)
        ck = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wk"])
        cv = jnp.einsum("bsd,dhe->bshe", enc_out, p["cross"]["wv"])
        if cfg.qkv_bias:
            ck, cv = ck + p["cross"]["bk"], cv + p["cross"]["bv"]
        a, _ = attention_apply(cfg, p["cross"], hx, rules=rules,
                               cross_kv=(ck, cv))
        x = x + a
        if want_cache:
            from repro.models.layers import kv_quant
            cache = {**cache, "xk": kv_quant(cfg, ck), "xv": kv_quant(cfg, cv)}
    h2 = norm_apply(p["ln2"], x, cfg.norm)
    if kind == "attn_moe":
        f, aux = moe_apply(cfg, p["moe"], h2, rules=rules)
    else:
        f = mlp_apply(cfg, p["mlp"], h2, rules=rules)
    return x + f, cache, aux


def _window_cache(cfg, kv, w):
    """Ring-buffer (slot = pos % w) cache from a full prefill kv."""
    from repro.models.layers import kv_quant
    k, v = kv_quant(cfg, kv[0]), kv_quant(cfg, kv[1])
    S = k.shape[1]
    if S <= w:
        pad = w - S
        return {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))}
    pos = jnp.arange(S - w, S)
    slots = pos % w
    ck = jnp.zeros((k.shape[0], w) + k.shape[2:], k.dtype).at[:, slots].set(
        k[:, pos])
    cv = jnp.zeros((v.shape[0], w) + v.shape[2:], v.dtype).at[:, slots].set(
        v[:, pos])
    return {"k": ck, "v": cv}


def block_decode(cfg, kind: str, p, x, cache, pos, *,
                 rules: Rules = NO_RULES, block_table=None,
                 win_block_table=None):
    """Decode block step. x: (B, T, d) — T == 1 for plain decode; paged
    blocks also take T > 1 speculative verify blocks (pos is the first
    row's position; see layers.attention_decode). Returns (x, new_cache).
    block_table switches the full-attention cache entries to the
    paged-pool layout; win_block_table does the same for local_attn
    layers (sliding-window pages, recycled as they leave the window —
    without it local_attn runs the dense ring buffer, single-token only).
    Recurrent kinds (ssm/rglru) hold per-slot state, not pages: T > 1
    runs a T-step recurrence returning checkpointed states (see
    ssm_decode / rglru_decode)."""
    h = norm_apply(p["ln1"], x, cfg.norm)
    if kind in ("attn_mlp", "attn_moe", "dec"):
        a, cache_a = attention_decode(cfg, p["attn"], h,
                                      {"k": cache["k"], "v": cache["v"]},
                                      pos, rules=rules,
                                      block_table=block_table)
    elif kind == "local_attn":
        a, cache_a = attention_decode(cfg, p["attn"], h,
                                      {"k": cache["k"], "v": cache["v"]},
                                      pos, rules=rules,
                                      window=cfg.hybrid.window,
                                      block_table=win_block_table)
    elif kind == "ssm":
        a, new_cache = ssm.ssm_decode(cfg, p["mixer"], h, cache, rules=rules)
        return x + a, new_cache
    elif kind == "rglru":
        a, new_cache = griffin.rglru_decode(cfg, p["mixer"], h, cache,
                                            rules=rules)
        x = x + a
        h2 = norm_apply(p["ln2"], x, cfg.norm)
        return x + mlp_apply(cfg, p["mlp"], h2, rules=rules), new_cache
    else:
        raise ValueError(kind)
    x = x + a
    new_cache = dict(cache_a)
    if kind == "dec":
        hx = norm_apply(p["lnx"], x, cfg.norm)
        a, _ = attention_decode(cfg, p["cross"], hx,
                                {"k": cache["xk"], "v": cache["xv"]},
                                pos, rules=rules, cross=True)
        x = x + a
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
    h2 = norm_apply(p["ln2"], x, cfg.norm)
    if kind == "attn_moe":
        f, _ = moe_apply(cfg, p["moe"], h2, rules=rules)
    else:
        f = mlp_apply(cfg, p["mlp"], h2, rules=rules)
    return x + f, new_cache


# ---------------------------------------------------------------------------
# Stacks (scan over super-blocks + tail)
# ---------------------------------------------------------------------------


def stack_init(mk: Maker, cfg, kinds: Tuple[str, ...], n_super: int,
               tail: Tuple[str, ...], key=None) -> Dict[str, Any]:
    """Stacked per-pattern-position params + tail params."""
    if mk.mode == "axes":
        one = {str(j): block_init(mk, cfg, k) for j, k in enumerate(kinds)}
        scan = jax.tree.map(lambda a: ("layers," + a) if a else "layers", one)
        return {"scan": scan,
                "tail": [block_init(mk, cfg, k) for k in tail]}
    keys = jax.random.split(key, n_super)

    def init_one(k):
        mkk = Maker("init", k, mk.dtype)
        return {str(j): block_init(mkk, cfg, kd) for j, kd in enumerate(kinds)}

    scan = jax.vmap(init_one)(keys) if n_super > 0 else {}
    tailp = []
    for t, kd in enumerate(tail):
        mkk = Maker("init", jax.random.fold_in(key, 10_000 + t), mk.dtype)
        tailp.append(block_init(mkk, cfg, kd))
    return {"scan": scan, "tail": tailp}


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(fn, policy=policy)


def stack_apply(cfg, params, x, kinds, tail, *, rules=NO_RULES,
                positions=None, enc_out=None, want_cache=False, max_len=None,
                prefix_kv=None, prefix_len=None, length=None,
                paged_kv=False):
    """Returns (x, caches, aux_sum). caches: {"scan": {j: stacked}, "tail": [..]}

    prefix_kv (same tree shape as the caches: {"scan": {j: {"k","v"}},
    "tail": [{"k","v"}]}, scan entries stacked (L, B, Pb, KV, D)) +
    prefix_len switch every full-attention block to suffix-only prefill
    against that reused KV; the per-layer slices ride the layer scan
    alongside the params."""

    def body(carry, sl):
        h, aux_acc = carry
        pslice, pfx = sl if prefix_kv is not None else (sl, None)
        caches = {}
        for j, kd in enumerate(kinds):
            pref = None
            if pfx is not None and kd in ("attn_mlp", "attn_moe"):
                pref = (pfx[str(j)]["k"], pfx[str(j)]["v"], prefix_len)
            h, c, aux = block_apply(cfg, kd, pslice[str(j)], h, rules=rules,
                                    positions=positions, enc_out=enc_out,
                                    want_cache=want_cache, max_len=max_len,
                                    prefix=pref, length=length,
                                    paged_kv=paged_kv)
            caches[str(j)] = c if c is not None else 0
            for k, v in aux.items():
                aux_acc[k] = aux_acc.get(k, 0.0) + v
        return (h, aux_acc), caches

    aux0 = {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}
    n_super = jax.tree.leaves(params["scan"])[0].shape[0] if params["scan"] else 0
    if n_super:
        xs = params["scan"] if prefix_kv is None \
            else (params["scan"], prefix_kv["scan"])
        (x, aux0), scan_caches = jax.lax.scan(_remat(cfg, body), (x, aux0),
                                              xs)
    else:
        scan_caches = {}
    tail_caches = []
    for t, (tp, kd) in enumerate(zip(params["tail"], tail)):
        pref = None
        if prefix_kv is not None and kd in ("attn_mlp", "attn_moe"):
            e = prefix_kv["tail"][t]
            pref = (e["k"], e["v"], prefix_len)
        x, c, aux = block_apply(cfg, kd, tp, x, rules=rules,
                                positions=positions, enc_out=enc_out,
                                want_cache=want_cache, max_len=max_len,
                                prefix=pref, length=length,
                                paged_kv=paged_kv)
        tail_caches.append(c if c is not None else 0)
        for k, v in aux.items():
            aux0[k] = aux0.get(k, 0.0) + v
    return x, {"scan": scan_caches, "tail": tail_caches}, aux0


def stack_decode(cfg, params, x, caches, pos, kinds, tail, *, rules=NO_RULES,
                 block_table=None, win_block_table=None):
    """Decode the whole stack one step. x: (B, T, d); T > 1 (a speculative
    multi-token block) requires every attention layer on a paged cache
    layout (block_table for full attention, win_block_table for sliding
    windows); recurrent layers then return checkpointed per-row states —
    see block_decode."""
    def body(h, sl):
        pslice, cslice = sl
        new_c = {}
        for j, kd in enumerate(kinds):
            h, nc = block_decode(cfg, kd, pslice[str(j)], h, cslice[str(j)],
                                 pos, rules=rules, block_table=block_table,
                                 win_block_table=win_block_table)
            new_c[str(j)] = nc
        return h, new_c

    n_super = jax.tree.leaves(params["scan"])[0].shape[0] if params["scan"] else 0
    if n_super:
        x, new_scan = jax.lax.scan(body, x, (params["scan"], caches["scan"]))
    else:
        new_scan = {}
    new_tail = []
    for tp, kd, tc in zip(params["tail"], tail, caches["tail"]):
        x, nc = block_decode(cfg, kd, tp, x, tc, pos, rules=rules,
                             block_table=block_table,
                             win_block_table=win_block_table)
        new_tail.append(nc)
    return x, {"scan": new_scan, "tail": new_tail}
