"""Pure-jnp oracles for every kernel (the correctness contract)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def gemm_ref(x: jax.Array, w: jax.Array, *, out_dtype=None,
             quant_scale: Optional[float] = None) -> jax.Array:
    integer = jnp.issubdtype(x.dtype, jnp.integer)
    acc = jnp.matmul(
        x, w, preferred_element_type=jnp.int32 if integer else jnp.float32)
    if quant_scale is not None:
        q = jnp.round(acc.astype(jnp.float32) * quant_scale)
        return jnp.clip(q, -128, 127).astype(jnp.int8)
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else x.dtype
    return acc.astype(out_dtype)


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True, kv_valid: Optional[int] = None
            ) -> jax.Array:
    """Exact softmax attention with GQA. q: (B,Sq,H,D); k,v: (B,Sk,KV,D)."""
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    kv_lim = Sk if kv_valid is None else kv_valid
    kpos = jnp.arange(Sk)
    mask = kpos[None, :] < kv_lim
    if causal:
        mask = mask & (jnp.arange(Sq)[:, None] >= kpos[None, :])
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_table: jax.Array, lengths, *,
                        kv_scale: Optional[float] = None,
                        window: int = 0) -> jax.Array:
    """Dense-gather oracle for the paged flash-decode kernel.

    Deliberately does the thing the kernel exists to avoid — gather every
    request's pages into a (B, n_blocks*page, KV, D) buffer — then runs an
    exact masked softmax. q: (B, H, D) or (B, T, H, D) (T-token query
    block, speculative verify); pools: (P, page, KV, D); block_table:
    (B, n_blocks); lengths: (B,) live tokens INCLUDING the q block (base +
    T): query row t sits at absolute position base + t and attends to
    lengths - T + t + 1 keys (T == 1 reduces to the old pos + 1 contract).
    window > 0 additionally bounds each row to keys at positions in
    (base + t - window, base + t] — buffer index == absolute position, so
    window-recycled (scratch) lead blocks are masked out by construction.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, T, H, D = q.shape
    _, page, KV, _ = k_pool.shape
    G = H // KV
    n_blocks = block_table.shape[1]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))

    def dq(x):
        if jnp.issubdtype(x.dtype, jnp.integer):
            return x.astype(jnp.float32) * (kv_scale / 127.0)
        return x.astype(jnp.float32)

    kg = dq(k_pool[block_table]).reshape(B, n_blocks * page, KV, D)
    vg = dq(v_pool[block_table]).reshape(B, n_blocks * page, KV, D)
    qg = q.reshape(B, T, KV, G, D).astype(jnp.float32)
    s = jnp.einsum("btkgd,bskd->btkgs", qg, kg) * (D ** -0.5)
    # row t sees keys at positions < base + t + 1 (base = lengths - T)
    kpos = jnp.arange(n_blocks * page)[None, None, :]
    qlen = (lengths[:, None] - T + jnp.arange(T)[None, :] + 1)[..., None]
    mask = kpos < qlen                                  # (B, T, S)
    if window > 0:
        mask = mask & (kpos >= qlen - window)
    s = jnp.where(mask[:, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", p, vg)
    o = o.reshape(B, T, H, D).astype(q.dtype)
    return o[:, 0] if squeeze else o


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1,
               padding: str = "SAME") -> jax.Array:
    """x: (N,H,W,C); w: (R,S,C,K) -> (N,HO,WO,K)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)


def blocked_layout_ref(x: jax.Array, cb: int) -> jax.Array:
    """(H, W, C) -> (C//cb, H, W, cb) — the C/8HWC8 transform at TPU lane
    granularity."""
    H, W, C = x.shape
    assert C % cb == 0
    return x.reshape(H, W, C // cb, cb).transpose(2, 0, 1, 3)


def transpose_ref(x: jax.Array) -> jax.Array:
    return x.T
