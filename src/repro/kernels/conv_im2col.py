"""Implicit-im2col Conv2D kernel — the 6-D AGU analogue on TPU.

The chip's input streamer walks a programmable 6-D affine address pattern
so Conv2D never materializes an im2col buffer. The TPU analogue: the
kernel itself computes the strided window addresses (the AGU role) and
reads them with strided in-VMEM slices — one output row per grid step,
accumulating over the R x S filter taps:

    for (kh, kw):  out[oh, :, :] += x[oh*st + kh, kw::st, :] @ w[kh, kw]

Grid = (N, OH, COUT/bn); the (R, S) loop is unrolled inside the kernel
(static), so each tap is one MXU matmul of an (OW, C) strided window
against a (C, bn) filter slice — implicit im2col, no gather buffers.

Note on residency: each grid step maps one padded input image (1, Hp, Wp,
C) into VMEM. That is the right shape for the small feature maps of the
deep layers this kernel targets; a production variant would add an OH-
strip BlockSpec for the large early layers (recorded in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params


def _conv_kernel(x_ref, w_ref, o_ref, *, R: int, S: int, stride: int,
                 OW: int):
    oh = pl.program_id(1)
    x = x_ref[0]                                  # (Hp, Wp, C)
    acc = jnp.zeros(o_ref.shape[2:], jnp.float32)  # (OW, bn)
    for kh in range(R):
        row = jax.lax.dynamic_index_in_dim(
            x, oh * stride + kh, axis=0, keepdims=False)   # (Wp, C)
        for kw in range(S):
            # strided window: input cols kw, kw+st, ... for all OW outputs
            win = jax.lax.slice(row, (kw, 0),
                                (kw + stride * (OW - 1) + 1, row.shape[1]),
                                (stride, 1))               # (OW, C)
            acc += jnp.dot(win, w_ref[kh, kw],
                           preferred_element_type=jnp.float32)
    o_ref[0, 0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("stride", "bn", "interpret"))
def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1, bn: int = 128,
           interpret: bool = True) -> jax.Array:
    """x: (N, H, W, C); w: (R, S, C, K). SAME padding. -> (N, HO, WO, K)."""
    N, H, W, C = x.shape
    R, S, _, K = w.shape
    OH, OW = -(-H // stride), -(-W // stride)
    # SAME padding (as lax.conv computes it)
    ph = max((OH - 1) * stride + R - H, 0)
    pw = max((OW - 1) * stride + S - W, 0)
    xp = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                     (pw // 2, pw - pw // 2), (0, 0)))
    bn = min(bn, K)
    pk = (-K) % bn
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pk))) if pk else w
    Kp = K + pk
    Hp, Wp = xp.shape[1], xp.shape[2]

    out = pl.pallas_call(
        functools.partial(_conv_kernel, R=R, S=S, stride=stride, OW=OW),
        grid=(N, OH, Kp // bn),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda n, oh, j: (n, 0, 0, 0)),
            pl.BlockSpec((R, S, C, bn), lambda n, oh, j: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, OW, bn),
                               lambda n, oh, j: (n, oh, 0, j)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, Kp), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(xp, wp)
    return out[..., :K]
