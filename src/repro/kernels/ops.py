"""Public jit'd wrappers for the kernel layer.

On a real TPU these dispatch to the Pallas kernels (compiled); everywhere
else they run the kernels in interpret mode (bit-comparable semantics, the
validation mode this repo uses on CPU) or fall back to the jnp reference.
The model stack (repro.models) keeps pure-jnp paths so XLA SPMD handles
sharding; the kernels are the per-chip compute layer a TPU deployment
swaps in (see DESIGN.md "Kernel integration").
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels import attention as _attention
from repro.kernels import conv_im2col as _conv
from repro.kernels import gemm_os as _gemm
from repro.kernels import paged_attention as _paged
from repro.kernels import ref as _ref
from repro.kernels import reshuffle as _reshuffle


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(x: jax.Array, w: jax.Array, *,
           block: Tuple[int, int, int] = (128, 128, 128),
           out_dtype=None) -> jax.Array:
    """Output-stationary 3D-blocked matmul (Voltra C1)."""
    return _gemm.gemm_os(x, w, block=block, out_dtype=out_dtype,
                         interpret=not _on_tpu())


def quant_matmul(x: jax.Array, w: jax.Array, scale: float, *,
                 block: Tuple[int, int, int] = (128, 128, 128)
                 ) -> jax.Array:
    """INT8 x INT8 -> INT32 accumulate -> fused quant epilogue -> INT8
    (Voltra C1 + C4)."""
    return _gemm.gemm_os(x, w, block=block, quant_scale=scale,
                         interpret=not _on_tpu())


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, kv_valid: Optional[int] = None,
              bq: int = 128, bk: int = 128) -> jax.Array:
    """Fused flash-MHA with on-the-fly K^T (Voltra C3/PDMA analogue)."""
    return _attention.mha(q, k, v, causal=causal, kv_valid=kv_valid,
                          bq=bq, bk=bk, interpret=not _on_tpu())


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_table: jax.Array, lengths, *,
                    kv_scale: Optional[float] = None,
                    window: int = 0) -> jax.Array:
    """Flash-decode over a paged KV pool: the block-table indirection runs
    INSIDE the kernel (scalar-prefetched table, page-granular KV tiles,
    online softmax), so the per-layer dense gather of the PR-1 serving
    path never materializes (Voltra's shared-memory streamers; DESIGN.md
    "Paged attention"). q: (B, H, D) single-token decode, or (B, T, H, D)
    T-token query block (speculative verify — in-sweep causal masking,
    same kernel, same page traffic); pools: (P, page, KV, D); block_table:
    (B, n_blocks); lengths: (B,) live tokens INCLUDING the q block
    (base + T; T == 1 reduces to the old pos + 1 contract). window > 0 =
    sliding-window attention (hybrid local_attn layers): rows see at most
    the last `window` keys, and pages entirely below the window — which
    the serving engine recycles to scratch — are skipped in-grid."""
    return _paged.paged_attention(q, k_pool, v_pool, block_table, lengths,
                                  kv_scale=kv_scale, window=window,
                                  interpret=not _on_tpu())


def conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    """Implicit-im2col Conv2D (6-D AGU analogue), SAME padding."""
    return _conv.conv2d(x, w, stride=stride, interpret=not _on_tpu())


def blocked_layout(x: jax.Array, cb: int = 128) -> jax.Array:
    return _reshuffle.blocked_layout(x, cb, interpret=not _on_tpu())


def transpose(x: jax.Array) -> jax.Array:
    return _reshuffle.tiled_transpose(x, interpret=not _on_tpu())


# re-export oracles for tests/benchmarks
ref = _ref
