"""Paged flash-decode attention kernel — the in-kernel block-table gather.

The serving engine's paged KV cache (repro.runtime.kv_cache) keeps every
layer's K/V in a shared page pool ``(P, page, KV, D)`` addressed through
per-request block tables. The PR-1 decode path gathered each request's
pages into a dense ``(B, n_blocks * page, KV, D)`` buffer per layer before
attending — exactly the "separated memory" data movement the paper's
shared-memory streamers avoid (PAPER.md §III: the flexible streamers fetch
the tiles the PEs consume, nothing else). This kernel moves the block-table
indirection *inside* the attention kernel, vLLM-style:

* the block table and per-request valid lengths ride in as **scalar
  prefetch** operands (``pltpu.PrefetchScalarGridSpec``), so the index map
  of the K/V pool can pick the physical page of grid step ``(b, h, i)``
  *before* the body runs — the pool is only ever touched one page at a
  time, straight from HBM into a VMEM tile;
* the grid walks ``(batch, logical_block)`` with the block axis
  innermost; one grid step streams one whole pool page ``(page, KV, D)``
  — the pool's contiguous unit, so the DMA is a single dense copy, never
  a strided per-head slice. Running max / denominator / output
  accumulator live in VMEM scratch across the page sweep (online
  softmax), so neither the gathered KV nor the score matrix ever exists
  outside a page-sized tile;
* GQA: all ``H = KV * G`` query heads ride the same streamed page (the
  chip's 3D-reuse argument applied to the KV stream) — the per-head
  score is a KV-batched ``(G, D) x (D, page)`` contraction;
* **multi-token query blocks** (speculative decode): ``q`` may carry
  ``T >= 1`` rows per request. The T axis is folded into the head-group
  axis — row ``r = t * G + g`` of a ``(KV, T*G, D)`` q tile — so the
  body stays the same KV-batched contraction while every row still rides
  the SAME streamed page (the verify step multiplies arithmetic
  intensity by T at unchanged page traffic). Causality is enforced
  in-sweep: query row ``t`` sits at absolute position ``base + t``
  (``base = lengths[b] - T``) and sees exactly ``base + t + 1`` keys;
* blocks past a request's valid length are skipped (``pl.when``), so a
  short request in a long-table batch pays for the pages it owns, not for
  ``max_blocks``;
* **sliding windows** (hybrid stacks, ``local_attn`` layers): a static
  ``window`` bounds how far back each query row may look. Grid steps
  whose page lies entirely below the earliest row's window start are
  skipped too — paired with the engine's page recycling
  (``runtime/kv_cache.release_prefix``) the sweep costs
  O(window / page) tiles per request however long its logical context
  grows — and the straddling page is trimmed by an extra in-sweep mask
  term (key position must exceed ``base + t - window``);
* int8 KV pools are dequantized tile-by-tile inside the kernel
  (``kv_scale``), so the f32 view of the cache never materializes either;
* **tensor parallelism** (ISSUE 6) needs NO kernel change: under the
  serving TP plan (``parallel/tp.py``) this kernel runs inside a
  ``shard_map`` body on each shard's LOCAL slice of the pool — ``KV`` is
  the per-shard KV-head count, ``q`` carries the matching ``H/M`` heads,
  and because GQA groups shard whole (heads and kv_heads divide the mesh
  together), ``H % KV == 0`` and the group fold are unchanged. Block
  tables and lengths are replicated, pages are local, and the online-
  softmax scratch never crosses shards — the psum happens later, at the
  out-projection (``layers.attention_decode``).

The pure-jnp oracle (dense gather + masked softmax) is
``repro.kernels.ref.paged_attention_ref``; dispatch (TPU compiled vs
interpret elsewhere) is ``repro.kernels.ops.paged_attention``. See
DESIGN.md "Paged attention" and "Speculative decode".
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_NEG = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  acc_ref, *, page: int, n_blocks: int, n_rows: int,
                  group: int, scale: float, dequant: Optional[float],
                  window: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    T = n_rows // group

    # skip pages entirely past this request's live tokens: the sweep costs
    # ceil(length/page) page tiles, not max_blocks (decode step >= 1 token,
    # so block 0 always runs in the full-causal case; windowed sweeps may
    # skip it, but the init/finalize pl.when blocks above/below run on
    # their grid steps regardless). With a window, pages entirely below
    # the EARLIEST query row's window start — key positions <=
    # base - window with base = length - T — are skipped as well: the
    # sweep touches O(window / page) live tiles however long the logical
    # context is (the engine recycles those pages; their table entries
    # point at scratch).
    run = i * page < length
    if window > 0:
        run = jnp.logical_and(run, (i + 1) * page > length - T - window + 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)             # (KV, T*G, D)
        k = k_ref[0]                                 # (page, KV, D) — the
        v = v_ref[0]                                 # pool's contiguous unit
        if dequant is not None:                      # int8 pool: tile dequant
            k = k.astype(jnp.float32) * dequant
            v = v.astype(jnp.float32) * dequant
        # KV-batched (T*G, D) x (D, page) contraction: every query row of
        # the T-token block AND every head of the group scores against the
        # single page they all share
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32),
            dimension_numbers=(((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32) * scale  # (KV, T*G, page)
        pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        # in-sweep causal mask: row r = t*G + g holds query token t, whose
        # absolute position is base + t with base = length - T; it may see
        # keys at positions < base + t + 1. T == 1 reduces to pos < length.
        # A sliding window additionally requires pos > base + t - window
        # (trims the straddling page; fully-dead pages were skipped above).
        t_row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) // group
        mask = pos < (length - T) + t_row + 1
        if window > 0:
            mask = jnp.logical_and(mask, pos > (length - T) + t_row - window)
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = (acc_ref[...] * corr[..., None]
                        + jax.lax.dot_general(
                            p, v.astype(jnp.float32),
                            dimension_numbers=(((2,), (0,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(i == n_blocks - 1)
    def _fin():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[..., None]
                    ).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_table: jax.Array, lengths, *,
                    kv_scale: Optional[float] = None, window: int = 0,
                    interpret: bool = True) -> jax.Array:
    """Flash-decode over a paged KV pool. Returns q's shape.

    q:           (B, H, D) — one new token per request — or (B, T, H, D),
                 a T-token query block per request (speculative verify;
                 post-rope, rows at absolute positions base .. base+T-1).
    k/v_pool:    (P, page, KV, D) shared page pools (bf16/f32 or int8).
    block_table: (B, n_blocks) int32 — logical block j of request b lives
                 in physical page ``block_table[b, j]`` (scratch page 0 for
                 never-written tails AND for window-recycled lead blocks;
                 masked out by ``lengths`` / ``window``).
    lengths:     (B,) int32 (or scalar) — live tokens per request
                 INCLUDING every token of the q block just written (i.e.
                 base + T). Traced. Row t attends causally to
                 ``lengths - T + t + 1`` keys.
    kv_scale:    static absmax bound when the pools are int8
                 (dequant = kv_scale / 127, matching layers.kv_dequant).
    window:      static sliding window (0 = full causal): row t sees only
                 keys at positions in ``(base + t - window, base + t]``.
                 Pages entirely below the window are skipped — the
                 serving engine recycles them (their table entries are
                 scratch), so a windowed layer's sweep AND footprint stay
                 O(window) however long the request runs.
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]                     # (B, H, D) -> (B, 1, H, D)
    B = q.shape[0]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    out = _paged(q, k_pool, v_pool, block_table, lengths,
                 kv_scale=kv_scale, window=window, interpret=interpret)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit,
                   static_argnames=("kv_scale", "window", "interpret"))
def _paged(q, k_pool, v_pool, block_table, lengths, *,
           kv_scale: Optional[float], window: int, interpret: bool
           ) -> jax.Array:
    B, T, H, D = q.shape
    P, page, KV, _ = k_pool.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    n_blocks = block_table.shape[1]
    dequant = None
    if jnp.issubdtype(k_pool.dtype, jnp.integer):
        assert kv_scale is not None, "int8 pools need kv_scale"
        dequant = kv_scale / 127.0

    # (B, T, H, D) -> (B, KV, T*G, D): heads h*G..(h+1)*G-1 share kv head h
    # (matching layers._qkv head order) and the T query rows fold into the
    # group axis — row r = t*G + g — so the whole (token block x head
    # group) rides one streamed page per grid step.
    qg = (q.reshape(B, T, KV, G, D).transpose(0, 2, 1, 3, 4)
          .reshape(B, KV, T * G, D))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,            # block_table, lengths
        grid=(B, n_blocks),
        in_specs=[
            pl.BlockSpec((1, KV, T * G, D), lambda b, i, bt, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, page, KV, D),
                         lambda b, i, bt, ln: (bt[b, i], 0, 0, 0)),
            pl.BlockSpec((1, page, KV, D),
                         lambda b, i, bt, ln: (bt[b, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, T * G, D),
                               lambda b, i, bt, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, T * G), jnp.float32),      # running max
            pltpu.VMEM((KV, T * G), jnp.float32),      # running denominator
            pltpu.VMEM((KV, T * G, D), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, page=page, n_blocks=n_blocks,
                          n_rows=T * G, group=G, scale=D ** -0.5,
                          dequant=dequant, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, T * G, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, lengths, qg, k_pool, v_pool)
    return (out.reshape(B, KV, T, G, D).transpose(0, 2, 1, 3, 4)
            .reshape(B, T, H, D))
