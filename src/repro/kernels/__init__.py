"""repro.kernels — Voltra's compute hot-spots as Pallas TPU kernels.

  gemm_os     — C1+C4: 3D-blocked output-stationary GeMM, fused INT8
                quant epilogue (pl.pallas_call + BlockSpec VMEM tiling)
  attention   — C3: fused flash-MHA, on-the-fly K^T, VMEM chain residency
  conv_im2col — 6-D AGU analogue: implicit-im2col Conv2D
  reshuffle   — data reshuffler: blocked layouts + tiled transpose
  maxpool     — Sec. II-E maxpool unit (arbitrary windows, lane-parallel)
  paged_attention — §III shared-memory streamers at serving time: flash-
                decode with the block-table gather inside the kernel
                (scalar-prefetched table, page-granular KV tiles)
  ops         — public jit'd wrappers (TPU: compiled; CPU: interpret)
  ref         — pure-jnp oracles (the correctness contract for tests)
"""
