"""Fused flash-MHA kernel with on-the-fly K^T — Voltra C3 (PDMA) on TPU.

The paper's Fig. 4 insight: keep the whole per-tile MHA chain
(S = Q K^T -> online softmax -> O = P V) resident in fast memory, with
K^T performed on the fly by the weight streamer's transposer instead of a
dedicated transpose pass. The TPU analogue keeps the chain in VMEM:

  * grid = (batch*kv_heads, Sq/bq, Sk/bk), K/V axis innermost;
  * K arrives in its natural (bk, d) layout and is transposed inside the
    kernel (`jnp.dot(q, k.T)`) — never materialized transposed in HBM;
  * running max / denominator / output accumulator live in VMEM scratch
    across the KV sweep (online softmax), so the (Sq, Sk) score matrix
    never exists outside VMEM tiles — the PDMA-style residency;
  * GQA: the q-head group of each kv head is folded into the q rows, so
    grouped heads share the streamed K/V blocks (the chip's data-reuse
    argument, applied to the KV stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

_NEG = -1e30


def _mha_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref,
                acc_ref, *, n_kv: int, bq: int, bk: int, scale: float,
                causal: bool, group: int):
    kv = pl.program_id(2)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (bq, d) — bq = group * q_rows
    k = k_ref[0]                       # (bk, d) — natural layout
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    kv_pos = kv * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # per-sequence valid length arrives as data (page-aware kv_valid), so
    # one compiled kernel serves every prompt length in a bucket
    mask = kv_pos < valid_ref[0]
    if causal:
        # q rows are (group, rows) flattened; absolute position of row r
        # is (r % (bq//group)) + query block offset
        rows = jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        q_pos = pl.program_id(1) * (bq // group) + rows % (bq // group)
        mask = mask & (q_pos >= kv_pos)
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    # fully-masked rows/blocks must contribute zero probability (exp of
    # (-1e30) - (-1e30) would otherwise be 1)
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(kv == n_kv - 1)
    def _fin():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
        bq: int = 128, bk: int = 128, kv_valid=None,
        interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0.

    Returns (B, Sq, H, D). The (Sq, Sk) score matrix is never materialized
    outside VMEM tiles.

    kv_valid: None (all Sk positions real), an int, or a (B,) int32 array —
    only the first kv_valid[b] kv positions of sequence b attend. It is a
    TRACED operand (streamed into the kernel per batch row), never a trace
    constant, so one compiled kernel serves every valid-length in a padded
    batch — the same bucket-stability contract the serving engine's
    bucketed prefill relies on (serving's jnp path lives in
    models/layers.flash_attention; this Pallas kernel is the TPU analogue
    reached via kernels/ops.attention).
    """
    B = q.shape[0]
    Sk = k.shape[1]
    if kv_valid is None:
        kv_valid = Sk
    kv_valid = jnp.broadcast_to(
        jnp.asarray(kv_valid, jnp.int32), (B,))
    return _mha(q, k, v, kv_valid, causal=causal, bq=bq, bk=bk,
                interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "bq", "bk", "interpret"))
def _mha(q: jax.Array, k: jax.Array, v: jax.Array, kv_valid: jax.Array, *,
         causal: bool, bq: int, bk: int, interpret: bool) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    G = H // KV
    scale = D ** -0.5

    # fold (kv_head, group) into the batch/q-row axes so grouped heads
    # share each streamed K/V block. Row layout inside a q block is
    # (group, seq_row): block i holds seq rows [i*bq0, (i+1)*bq0) for all
    # G groups — the causal mask in the kernel relies on this.
    bq0 = min(bq, Sq)
    pq = (-Sq) % bq0
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    Sqp = Sq + pq
    nq = Sqp // bq0
    qf = (qp.reshape(B, nq, bq0, KV, G, D).transpose(0, 3, 1, 4, 2, 5)
          .reshape(B * KV, nq * G * bq0, D))
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)

    bq_eff = bq0 * G                    # whole group shares each q block
    pk = (-Sk) % bk
    if pk:
        kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
    Skp = Sk + pk
    n_kv = Skp // bk

    validf = jnp.repeat(kv_valid, KV)   # (B*KV,) — one row per b/kv program

    out = pl.pallas_call(
        functools.partial(
            _mha_kernel, n_kv=n_kv, bq=bq_eff, bk=bk, scale=scale,
            causal=causal, group=G),
        grid=(B * KV, nq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq_eff, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1,), lambda b, i, j: (b,)),
        ],
        out_specs=pl.BlockSpec((1, bq_eff, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, nq * G * bq0, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_eff,), jnp.float32),
            pltpu.VMEM((bq_eff,), jnp.float32),
            pltpu.VMEM((bq_eff, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf, validf)

    out = (out.reshape(B, KV, nq, G, bq0, D).transpose(0, 2, 4, 1, 3, 5)
           .reshape(B, Sqp, KV, G, D))
    return out[:, :Sq].reshape(B, Sq, H, D)
