"""3D-blocked output-stationary GeMM kernel — Voltra C1 + C4 on TPU.

The TPU realization of the paper's 3D spatial array:

  * the (bm, bn, bk) BlockSpec tiling is the balanced 3-axis unrolling —
    grid = (M/bm, N/bn, K/bk) with the K axis innermost/sequential;
  * output-stationarity: the fp32/int32 accumulator tile lives in VMEM
    scratch for the whole K sweep (the array's accumulation registers) and
    is written out exactly once — high-precision partial sums never touch
    HBM, just as the chip never spills them to the shared memory;
  * the quantization SIMD unit (C4) is the fused epilogue: on the last K
    step the accumulator is scaled/clipped/rounded to INT8 while still in
    VMEM — no second pass over the output in HBM;
  * mixed-grained prefetching (C2) maps onto the Pallas grid pipeline:
    the next (x, w) blocks stream HBM->VMEM while the MXU consumes the
    current ones (a depth-2 hardware FIFO per operand).

Hardware adaptation (DESIGN.md): the chip unrolls 8x8x8; the MXU wants
128-multiples, so default blocks are (128, 128, 128)-class and tile-edge
utilization math happens at that granularity instead.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int,
                 quant_scale: Optional[float]):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if quant_scale is not None:
            # fused quantization SIMD: scale -> round -> clip -> int8,
            # performed on the VMEM-resident accumulator tile
            q = jnp.round(acc.astype(jnp.float32) * quant_scale)
            o_ref[...] = jnp.clip(q, -128, 127).astype(o_ref.dtype)
        else:
            o_ref[...] = acc.astype(o_ref.dtype)


def _pad_to(x: jax.Array, mults: Tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads)
    return x


@functools.partial(
    jax.jit,
    static_argnames=("block", "out_dtype", "quant_scale", "interpret"))
def gemm_os(x: jax.Array, w: jax.Array, *,
            block: Tuple[int, int, int] = (128, 128, 128),
            out_dtype=None,
            quant_scale: Optional[float] = None,
            interpret: bool = True) -> jax.Array:
    """out[M, N] = x[M, K] @ w[K, N], output-stationary over K blocks.

    INT8 inputs accumulate in INT32 (the chip's datapath); float inputs in
    FP32. ``quant_scale`` enables the fused INT8 epilogue (out_dtype is
    then int8). Shapes are padded up to block multiples (the spatial-
    utilization edge effect — the padding fraction IS (1 - spatial util)).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = block
    integer = jnp.issubdtype(x.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    if out_dtype is None:
        out_dtype = jnp.int8 if quant_scale is not None else (
            jnp.int32 if integer else x.dtype)

    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    Mp, Kp = xp.shape
    _, Np = wp.shape
    n_k = Kp // bk

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k, quant_scale=quant_scale),
        grid=(Mp // bm, Np // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xp, wp)
    return out[:M, :N]


def spatial_utilization(M: int, K: int, N: int,
                        block: Tuple[int, int, int] = (128, 128, 128)
                        ) -> float:
    """Tile-edge efficiency of the 3D blocking — the same formula as the
    chip's spatial utilization (core/spatial.py), at MXU granularity."""
    bm, bn, bk = block

    def eff(d, b):
        return d / (b * -(-d // b))

    return eff(M, bm) * eff(N, bn) * eff(K, bk)
