"""Data-reshuffler kernels — Sec. II-E on TPU.

The chip's reshuffler converts layouts so the streamers can fetch
conflict-free:

  * ``blocked_layout`` — HWC -> C/cb HWC cb. On the chip cb=8 (one 64-bit
    bank word of channels); on TPU cb=128 (one lane register) so that a
    conv window read is lane-contiguous (hardware adaptation, DESIGN.md).
  * ``tiled_transpose`` — the *dedicated transposer* baseline the paper
    compares its on-the-fly streamer transposer against (attention.py is
    the on-the-fly version: it never runs this pass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blocked_kernel(x_ref, o_ref):
    o_ref[0] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("cb", "interpret"))
def blocked_layout(x: jax.Array, cb: int = 128, *,
                   interpret: bool = True) -> jax.Array:
    """(H, W, C) -> (C//cb, H, W, cb); C padded up to a cb multiple."""
    H, W, C = x.shape
    pc = (-C) % cb
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, pc))) if pc else x
    Cp = C + pc
    return pl.pallas_call(
        _blocked_kernel,
        grid=(Cp // cb,),
        in_specs=[pl.BlockSpec((H, W, cb), lambda j: (0, 0, j))],
        out_specs=pl.BlockSpec((1, H, W, cb), lambda j: (j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Cp // cb, H, W, cb), x.dtype),
        interpret=interpret,
    )(xp)


def _transpose_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def tiled_transpose(x: jax.Array, *, block: int = 128,
                    interpret: bool = True) -> jax.Array:
    """(M, N) -> (N, M) via VMEM tiles (the dedicated-transposer pass)."""
    M, N = x.shape
    b = block
    pm, pn = (-M) % b, (-N) % b
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    Mp, Np = xp.shape
    out = pl.pallas_call(
        _transpose_kernel,
        grid=(Mp // b, Np // b),
        in_specs=[pl.BlockSpec((b, b), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((b, b), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((Np, Mp), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:N, :M]
