"""Maxpool unit — Sec. II-E on TPU.

The chip's maxpool module has eight parallel comparison lanes and handles
arbitrary window sizes sequentially. The TPU analogue: grid over output
rows, lanes = the channel vector, the (R x S) window reduced by a static
sequential max loop inside the kernel — same structure, lane-width 128
instead of 8 (hardware adaptation)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params


def _maxpool_kernel(x_ref, o_ref, *, R: int, S: int, stride: int, OW: int):
    oh = pl.program_id(1)
    x = x_ref[0]                                   # (Hp, Wp, C)
    out = jnp.full(o_ref.shape[2:], -jnp.inf, jnp.float32)
    for kh in range(R):                            # sequential window walk
        row = jax.lax.dynamic_index_in_dim(x, oh * stride + kh, 0, False)
        for kw in range(S):
            win = jax.lax.slice(row, (kw, 0),
                                (kw + stride * (OW - 1) + 1, row.shape[1]),
                                (stride, 1))       # (OW, C)
            out = jnp.maximum(out, win.astype(jnp.float32))
    o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "stride",
                                             "interpret"))
def maxpool2d(x: jax.Array, *, window: int = 2, stride: int = 2,
              interpret: bool = True) -> jax.Array:
    """x: (N, H, W, C), VALID padding -> (N, OH, OW, C)."""
    N, H, W, C = x.shape
    R = S = window
    OH = (H - R) // stride + 1
    OW = (W - S) // stride + 1
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, R=R, S=S, stride=stride, OW=OW),
        grid=(N, OH),
        in_specs=[pl.BlockSpec((1, H, W, C), lambda n, oh: (n, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, OW, C), lambda n, oh: (n, oh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, C), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)


def maxpool2d_ref(x: jax.Array, *, window: int = 2, stride: int = 2
                  ) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")
