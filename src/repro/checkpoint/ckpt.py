"""Sharded npz checkpointing with async save and cross-mesh resharding.

No orbax in this environment, so this is a from-scratch production-shaped
implementation:
  * atomic writes (tmp dir + rename) — a preempted save never corrupts state;
  * flat key/value layout (pytree paths -> arrays) + a JSON manifest;
  * async save off the critical path (background thread, joinable);
  * restore accepts a *different* mesh/sharding than the one that saved —
    arrays are loaded on host and re-device_put with the new sharding
    (elastic restart across pod counts);
  * data-pipeline state and step counter are part of the checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = leaf
    return flat


def save(path: str, state, *, extra: Optional[Dict[str, Any]] = None) -> None:
    """Atomic synchronous save of a pytree of arrays."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    arrs = {}
    manifest = {"keys": [], "dtypes": {}, "extra": extra or {}}
    for k, v in flat.items():
        # repro-lint: disable=host-sync — checkpoint save IS the D2H copy
        a = np.asarray(jax.device_get(v))
        if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
            # npz cannot round-trip ml_dtypes (bfloat16 etc.): store the
            # raw bits and record the true dtype in the manifest
            manifest["dtypes"][k] = a.dtype.name
            a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
        arrs[k] = a
        manifest["keys"].append(k)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrs)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (one in flight).

    A failed background save is NEVER silent: the exception is re-raised
    on the next ``save()`` or ``wait()`` — whichever comes first — and
    counted in ``failed_saves`` so telemetry consumers (the trainer, the
    host-tier swap-out path in ``runtime/host_tier.py``, which persists
    swap records through this class) see the failure even if they poll
    instead of joining. ``last_error`` is readable without consuming it;
    raising clears it so one failure surfaces exactly once."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.last_error: Optional[BaseException] = None
        self.completed_saves = 0
        self.failed_saves = 0

    def save(self, path: str, state, *, extra=None) -> None:
        # join + re-raise FIRST: a caller that only ever calls save() in a
        # loop still sees the previous save's failure before work based on
        # the assumption it succeeded is queued
        self.wait()
        # device_get on the caller thread (cheap on CPU; on TPU this is the
        # D2H copy we deliberately take before releasing the step).
        # repro-lint: disable=host-sync — the pre-async snapshot named above
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            try:
                save(path, host_state, extra=extra)
                with self._lock:
                    self.completed_saves += 1
            except BaseException as e:  # surfaced on next save()/wait()
                with self._lock:
                    self.last_error = e
                    self.failed_saves += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            err, self.last_error = self.last_error, None
        if err is not None:
            raise err


def restore(path: str, like, *, shardings=None):
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs). `shardings` (matching pytree or None) enables
    cross-mesh resharding: host arrays are device_put with the new sharding.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like)
    missing = [k for k in flat_like if k not in data]
    if missing:
        raise KeyError(f"checkpoint at {path} missing keys: {missing[:5]}...")
    flat_sh = _flatten(shardings) if shardings is not None else None
    out = {}
    dtypes = manifest.get("dtypes", {})
    for k, ref in flat_like.items():
        arr = data[k]
        if k in dtypes:   # stored as raw bits (bfloat16 etc.)
            arr = arr.view(jax.numpy.dtype(dtypes[k]))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{k}: ckpt shape {arr.shape} != {ref.shape}")
        arr = arr.astype(ref.dtype)
        if flat_sh is not None:
            out[k] = jax.device_put(arr, flat_sh[k])
        else:
            out[k] = jax.numpy.asarray(arr)
    treedef = jax.tree.structure(like)
    leaves_keys = list(_flatten(like).keys())
    return jax.tree.unflatten(treedef, [out[k] for k in leaves_keys]), \
        manifest["extra"]


def latest_step_dir(root: str) -> Optional[str]:
    """Find the newest step_XXXX checkpoint under root (resume-on-restart)."""
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append((int(name.split("_")[1]), name))
            except ValueError:
                pass
    if not steps:
        return None
    return os.path.join(root, max(steps)[1])
