"""Deterministic synthetic LM data pipeline.

Production posture: per-host sharded, stateful (checkpointable cursor),
packed fixed-length sequences. The generator is a counter-based PRNG stream,
so any (host, step) batch is reproducible after elastic restart — no data
files needed, same contract as a sharded tokenized corpus reader.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 1234
    # structured synthetic task: next-token = (token * a + b) % vocab on
    # marked spans, so a real model can actually learn (loss goes down).
    learnable: bool = True


class SyntheticDataset:
    """Stateful, checkpointable synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.step = 0

    # -- checkpointable state ------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"step": self.step}

    def load_state_dict(self, s: Dict[str, Any]) -> None:
        self.step = int(s["step"])

    # -- batches ---------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 131 + self.cfg.host_id)

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b = cfg.global_batch // cfg.num_hosts
        rng = self._rng(self.step)
        if cfg.learnable:
            # affine-mod sequences: x_{t+1} = (a*x_t + c) % V with per-sample
            # (a, c); learnable by a small LM, non-trivial (needs context).
            a = rng.integers(2, 8, size=(b, 1))
            c = rng.integers(1, 64, size=(b, 1))
            x0 = rng.integers(0, cfg.vocab, size=(b, 1))
            toks = np.empty((b, cfg.seq_len + 1), np.int64)
            toks[:, :1] = x0
            for t in range(cfg.seq_len):
                toks[:, t + 1] = (toks[:, t] * a[:, 0] + c[:, 0]) % cfg.vocab
        else:
            toks = rng.integers(0, cfg.vocab, size=(b, cfg.seq_len + 1))
        self.step += 1
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
