"""Data-parallel replica router: whole-engine replicas over device slices.

Tensor parallelism (``parallel/tp.py``) scales ONE engine across the
``model`` axis of its mesh; this module scales *throughput* the orthogonal
way — R independent ``PagedServingEngine`` replicas, each owning a
disjoint slice of ``jax.devices()`` (``make_replicas``), each running its
own continuous-batching ``Scheduler`` loop. The two compose: a replica
may itself be an M-way TP engine, so R x M devices serve as R replicas
of M shards (the paper's bank-parallel shared memory tiled twice over).

Routing policies (``policy=``):

* ``"hash"`` — ``rid % R``: stateless, sticky (a resubmitted/preempted
  request lands on the replica that still caches its prefix), the
  default.
* ``"least_loaded"`` — the replica with the fewest in-flight tokens
  (queued prompt+budget plus live slots' outstanding work) at submit
  time: better tail latency under skewed traffic, at the cost of losing
  prefix-cache affinity.

``step()`` ticks every replica once (round-robin fairness is the trivial
kind: each tick advances every live replica exactly one scheduling
round); ``drain`` bounds the *per-replica* step budget like
``Scheduler.drain``. ``stats()`` rolls up per-replica allocator /
telemetry counters with ``replicas x`` totals plus the per-replica
breakdown, so pool pressure on one replica is visible rather than
averaged away.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax

from repro.runtime.scheduler import Scheduler, SchedulerExhausted
from repro.runtime.serving import Request

_POLICIES = ("hash", "least_loaded")


def make_replicas(cfg, params, *, replicas: int = 1, model: int = 1,
                  devices: Optional[Sequence] = None, **engine_kwargs
                  ) -> "ReplicaRouter":
    """Build R paged engines on disjoint ``model``-wide device slices and
    wrap them in a router. ``replicas * model`` must not exceed the
    visible device count; ``model == 1`` builds plain single-shard
    engines (no mesh), so the single-device default keeps working."""
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.serving import PagedServingEngine

    devs = list(devices) if devices is not None else list(jax.devices())
    policy = engine_kwargs.pop("policy", "hash")
    need = replicas * model
    if replicas < 1 or need > len(devs):
        raise ValueError(
            f"make_replicas: {replicas} replica(s) x {model} shard(s) "
            f"need {need} device(s), have {len(devs)}")
    engines = []
    for i in range(replicas):
        slice_ = devs[i * model:(i + 1) * model]
        mesh = make_host_mesh(model=model, devices=slice_) \
            if model > 1 else None
        engines.append(PagedServingEngine(cfg, params, mesh=mesh,
                                          **engine_kwargs))
    return ReplicaRouter(engines, policy=policy)


class ReplicaRouter:
    """Dispatch requests across replica engines; one Scheduler each."""

    def __init__(self, engines: List, *, policy: str = "hash"):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        if policy not in _POLICIES:
            raise ValueError(
                f"policy must be one of {_POLICIES}: {policy!r}")
        self.engines = list(engines)
        self.scheds = [Scheduler(e) for e in self.engines]
        self.policy = policy
        self.routed: List[int] = [0] * len(self.engines)

    # -- routing ----------------------------------------------------------
    def _load(self, i: int) -> int:
        """In-flight token estimate for replica i: queued work plus what
        its live slots still owe."""
        sched, eng = self.scheds[i], self.engines[i]
        queued = sum(len(r.prompt) + r.max_new - len(r.generated)
                     for r in sched.pending)
        live = sum(len(r.prompt) + r.max_new
                   for r in getattr(eng, "live", []) if r is not None)
        return queued + live

    def _pick(self, req: Request) -> int:
        if self.policy == "hash":
            return req.rid % len(self.engines)
        return min(range(len(self.engines)), key=self._load)

    def submit(self, req: Request) -> None:
        """Route and enqueue (admission happens on the replica's next
        tick, so a momentarily-full replica queues rather than drops)."""
        i = self._pick(req)
        self.routed[i] += 1
        tr = getattr(self.engines[i], "trace", None)
        if tr:
            tr.instant("dispatch", tid="router",
                       args={"rid": req.rid, "replica": i,
                             "policy": self.policy})
        self.scheds[i].add(req)

    add = submit                      # Scheduler-compatible spelling

    # -- driving ----------------------------------------------------------
    def has_work(self) -> bool:
        return any(s.pending or s.engine.has_live() for s in self.scheds)

    def step(self) -> None:
        """One round: tick every replica that has work. Replicas are
        independent single-engine loops — the router adds no cross-replica
        sync; a tick is host-sequential here, concurrent across hosts in
        a real deployment."""
        for s in self.scheds:
            if s.pending or s.engine.has_live():
                s.tick()

    def drain(self, max_steps: int = 10_000) -> None:
        """Run until every replica is empty; ``max_steps`` bounds each
        replica's OWN budget (Scheduler.drain semantics), so one wedged
        replica fails loudly instead of starving the loop."""
        rounds = 0
        while self.has_work():
            if rounds >= max_steps:
                busy = [i for i, s in enumerate(self.scheds)
                        if s.pending or s.engine.has_live()]
                raise SchedulerExhausted(
                    f"router drain exhausted {max_steps} rounds with "
                    f"replica(s) {busy} still busy")
            self.step()
            rounds += 1

    def run_to_completion(self, requests: List[Request],
                          max_steps: int = 10_000) -> List[Request]:
        for r in requests:
            self.submit(r)
        self.drain(max_steps)
        return [r for r in requests if r.done]

    # -- telemetry --------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Rolled-up telemetry: totals across replicas + per-replica
        breakdowns (peak pages per replica per shard is the capacity-
        planning number; a total would hide the hot replica)."""
        pool = [e.pool_stats() for e in self.engines]
        shard = [e.shard_stats() for e in self.engines]
        return {
            "replicas": len(self.engines),
            "policy": self.policy,
            "routed": list(self.routed),
            "decode_steps": sum(e.decode_steps for e in self.engines),
            "decoded_tokens": sum(e.decoded_tokens for e in self.engines),
            "preempted": sum(s.preempted for s in self.scheds),
            "peak_pages_per_replica": [p.peak_pages for p in pool],
            "allocated_pages_per_replica": [p.allocated_pages
                                            for p in pool],
            "model_shards": [s["model_shards"] for s in shard],
            "peak_pages_per_shard": [s["peak_pages_per_shard"]
                                     for s in shard],
        }
