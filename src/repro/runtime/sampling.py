"""Per-request decode policies (ISSUE 9): ``SamplingParams`` + the fused
batched sampler both serving engines emit tokens through.

The contract that makes per-request policies free at trace time:

* **Policies are operands, not constants.** A request's (temperature,
  top_k, top_p, seed) ride into the jitted step as stacked ``(B,)``
  device arrays (``policy_operands``), so one trace per prefill bucket /
  step shape serves ANY mix of greedy and sampled requests — no retrace
  per temperature value.
* **Greedy is the temperature=0 row of the sampled program.** A
  categorical draw at temperature t is ``argmax(z + G)`` with ``z`` the
  masked, temperature-scaled logits and ``G`` i.i.d. Gumbel noise; rows
  with t <= 0 multiply the noise by zero and reduce to the exact argmax
  the pre-ISSUE-9 engine computed (top-k/top-p masks always keep the
  top-1 token, so they never perturb a greedy row).
* **Per-request PRNG, position-indexed.** The key for the draw that
  decides generated token ``idx`` of request ``rid`` is
  ``fold_in(fold_in(fold_in(key(seed), rid), idx), draw)`` — a pure
  function of (seed, rid, idx), independent of batch composition, slot
  assignment, shard count or preemption history. A preempted request
  that resumes by re-prefill (or swap-in) replays the identical token
  stream; the same request served by the dense engine, the gather or
  kernel attention impl, or any TP shard count draws the same tokens.
  ``draw`` separates the independent uses of one position's key:
  ``ACCEPT_DRAW`` (speculative acceptance test) vs ``SAMPLE_DRAW``
  (the token draw itself), so the non-speculative engine and a verify
  step that rejects every draft consume the same sample stream.

Rejection-sampled speculative verification (the rule
``runtime/serving.py``'s verify step applies per drafted token): both
drafters propose deterministically (greedy argmax of the draft model /
n-gram lookup), so the proposal distribution q is a point mass and the
standard accept rule ``u < min(1, p(x)/q(x))`` reduces to ``u < p(x)``
with ``p`` the target policy's (masked, scaled) softmax. On first
rejection the engine emits a sample from the residual distribution —
``p`` with the rejected draft's mass removed and renormalized, i.e. a
gumbel-argmax over ``z`` with the draft token masked out. Marginally
each emitted token is distributed exactly as a non-speculative sample
(P(emit y != x) = (1 - p(x)) * p(y)/(1 - p(x)) = p(y)); at temperature
0, ``p`` is a point mass on the argmax, so "accept iff draft == argmax,
residual sample = argmax" — token-identical to the exact-greedy
verification it generalizes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# Filtered-out logits get a large finite penalty rather than -inf: an
# all-masked row (possible in intermediate spellings like a residual
# whose support emptied) then still argmaxes deterministically instead
# of propagating NaN through softmax.
NEG_FILTER = -1e30

# fold_in tags separating the independent draws one generated position
# may consume (see module docstring)
ACCEPT_DRAW = 0
SAMPLE_DRAW = 1


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy, carried on ``Request.params`` from
    ``submit()`` into the traced step. Defaults are exact greedy.

    temperature: 0 = greedy (argmax); > 0 scales logits by 1/t before
        the categorical draw.
    top_k: keep only the k highest logits (0 = no top-k cut).
    top_p: nucleus filtering — keep the smallest prefix of the sorted
        distribution with cumulative mass >= top_p (1.0 = no cut).
    seed: per-request PRNG seed; None uses the engine's seed. Tokens
        are a pure function of (seed, rid, generated-token index).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None

    def validate(self) -> "SamplingParams":
        if not self.temperature >= 0.0:
            raise ValueError(
                f"temperature must be >= 0 (0 = greedy): {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off): {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1] (1 = off): {self.top_p}")
        return self

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplingParams()


def policy_operands(policies: Sequence[Optional[SamplingParams]],
                    rids: Sequence[int], idxs: Sequence[int],
                    default_seed: int):
    """Stack per-slot policies into the ``(B,)`` device operands the
    jitted programs consume: one dict pytree of six arrays. Dead slots
    pass ``None`` policies (greedy rows whose output the live mask
    discards). ``idxs[i]`` is slot i's next generated-token index —
    ``len(req.generated)`` — the position the step's draw decides."""
    B = len(policies)
    temp = np.zeros((B,), np.float32)
    top_k = np.zeros((B,), np.int32)
    top_p = np.ones((B,), np.float32)
    seed = np.zeros((B,), np.int32)
    for i, p in enumerate(policies):
        p = p if p is not None else GREEDY
        temp[i] = p.temperature
        top_k[i] = p.top_k
        top_p[i] = p.top_p
        s = p.seed if p.seed is not None else default_seed
        seed[i] = np.int32(s & 0x7FFFFFFF)
    return {
        "temp": jnp.asarray(temp),
        "top_k": jnp.asarray(top_k),
        "top_p": jnp.asarray(top_p),
        "seed": jnp.asarray(seed),
        "rid": jnp.asarray(np.asarray(rids, np.int32)),
        "idx": jnp.asarray(np.asarray(idxs, np.int32)),
    }


def fold_keys(seed, rid, idx) -> jax.Array:
    """(B,) int32 operands -> (B,) typed PRNG keys:
    ``fold_in(fold_in(key(seed), rid), idx)``."""
    def one(s, r, i):
        return jax.random.fold_in(jax.random.fold_in(
            jax.random.key(s), r), i)

    return jax.vmap(one)(seed, rid, idx)


def draw_keys(keys, draw: int) -> jax.Array:
    """Split a position's key into its independent draws (ACCEPT_DRAW /
    SAMPLE_DRAW)."""
    return jax.vmap(lambda k: jax.random.fold_in(k, draw))(keys)


def scale_mask(logits, temp, top_k, top_p) -> jax.Array:
    """Temperature-scale then top-k/top-p-filter a (B, V) logit batch,
    rowwise. Returns f32 ``z`` with filtered entries at ``NEG_FILTER``;
    ``softmax(z)`` is the policy's target distribution p and
    ``argmax(z)`` its greedy token. Rows with temp <= 0 skip the scale
    (argmax is scale-invariant and both masks keep the top-1 token, so
    greedy rows are exact argmax rows regardless of k/p)."""
    V = logits.shape[-1]
    z = logits.astype(jnp.float32)
    z = z / jnp.where(temp > 0, temp, 1.0)[:, None]
    # top-k: value threshold at the k-th largest, rows with k<=0 exempt
    srt = jnp.sort(z, axis=-1)[..., ::-1]
    kth = jnp.take_along_axis(
        srt, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1)
    z = jnp.where((z >= kth) | (top_k <= 0)[:, None], z, NEG_FILTER)
    # top-p (nucleus) on the top-k survivors: keep the smallest sorted
    # prefix whose cumulative mass reaches p (the token that crosses the
    # boundary is kept: cum - prob < p)
    srt = jnp.sort(z, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(srt, axis=-1)
    keep_sorted = (jnp.cumsum(probs, axis=-1) - probs) < top_p[:, None]
    n_keep = jnp.maximum(keep_sorted.sum(-1), 1)
    pth = jnp.take_along_axis(srt, (n_keep - 1)[:, None], axis=-1)
    return jnp.where((z >= pth) | (top_p >= 1.0)[:, None], z, NEG_FILTER)


def gumbel_argmax(z, temp, keys) -> jax.Array:
    """The fused categorical-or-greedy draw: per-row Gumbel noise is
    zeroed where temp <= 0, so ``argmax(z + noise)`` is a categorical
    sample from softmax(z) on sampled rows and the exact argmax on
    greedy rows — one program, no branch, no retrace."""
    g = jax.vmap(lambda k: jax.random.gumbel(
        k, z.shape[-1:], jnp.float32))(keys)
    return jnp.argmax(
        z + jnp.where(temp > 0, 1.0, 0.0)[:, None] * g,
        axis=-1).astype(jnp.int32)


def sample_rows(logits, pol, offset: int = 0) -> jax.Array:
    """Sample one token per row of a (B, V) logit batch under the
    stacked policies ``pol`` (a ``policy_operands`` pytree). ``offset``
    shifts the generated-token index (a verify step's row t decides
    position idx + t). Callers slice logits to the real vocab first."""
    z = scale_mask(logits, pol["temp"], pol["top_k"], pol["top_p"])
    keys = draw_keys(
        fold_keys(pol["seed"], pol["rid"], pol["idx"] + offset),
        SAMPLE_DRAW)
    return gumbel_argmax(z, pol["temp"], keys)


def request_params(req, default: SamplingParams) -> SamplingParams:
    """Resolve a request's effective policy: its own ``params`` if set,
    else the engine default — validated either way."""
    p = getattr(req, "params", None)
    return (p if p is not None else default).validate()


def summarize(policies: List[Optional[SamplingParams]]) -> str:
    """Human-readable policy mix for logs/telemetry."""
    live = [p for p in policies if p is not None]
    n_greedy = sum(1 for p in live if p.is_greedy)
    return f"{n_greedy} greedy / {len(live) - n_greedy} sampled"
