"""Training runtime: step builder + fault-tolerant loop.

Production posture:
  * gradient accumulation via `lax.scan` over microbatches;
  * optional int8 gradient compression with error feedback;
  * async checkpointing off the critical path, atomic on disk;
  * auto-resume from the latest checkpoint (preemption-safe — tested by
    killing and restarting the loop mid-run);
  * straggler monitor: EWMA of step time, slow steps flagged (the hook a
    cluster scheduler would use to evict/replace a slow host);
  * data-pipeline cursor checkpointed with the model.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.models import api
from repro.optim import adamw
from repro.parallel import compression
from repro.parallel.sharding import NO_RULES, Rules


# ---------------------------------------------------------------------------
# Step builder
# ---------------------------------------------------------------------------


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, *, rules: Rules = NO_RULES,
                    grad_accum: int = 1, compress_grads: bool = False):
    """Returns step(state, batch) -> (state, metrics). state:
    {params, opt, [err]}. batch: {tokens, labels, ...} with global shapes."""

    def loss_fn(p, b):
        return api.loss_fn(cfg, p, b, rules=rules)

    def step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

            micro_b = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), micro_b)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = lsum / grad_accum
            aux = {}
        if compress_grads:
            grads, new_err = compression.compress_tree(grads, state["err"])
        new_p, new_opt, om = adamw.apply(opt_cfg, grads, state["opt"], params)
        new_state = {"params": new_p, "opt": new_opt}
        if compress_grads:
            new_state["err"] = new_err
        metrics = {"loss": loss, **om}
        if isinstance(aux, dict) and "ce" in aux:
            metrics["ce"] = aux["ce"]
        return new_state, metrics

    return step


def init_state(cfg, opt_cfg: adamw.AdamWConfig, key, *,
               compress_grads: bool = False) -> Dict[str, Any]:
    params = api.init_params(cfg, key)
    state = {"params": params, "opt": adamw.init(opt_cfg, params)}
    if compress_grads:
        state["err"] = compression.init_error_tree(params)
    return state


# ---------------------------------------------------------------------------
# Fault-tolerant loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor; flags steps slower than `threshold` x EWMA.

    On a real cluster the flag feeds the controller that drains/replaces the
    slow host; here it is surfaced in metrics and the trainer log."""
    alpha: float = 0.1
    threshold: float = 2.0
    ewma: Optional[float] = None
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        if slow:
            self.slow_steps += 1
        return slow


class Trainer:
    def __init__(self, cfg, opt_cfg, dataset, *, rules: Rules = NO_RULES,
                 ckpt_dir: Optional[str] = None, save_every: int = 50,
                 grad_accum: int = 1, compress_grads: bool = False,
                 seed: int = 0, log_every: int = 10,
                 log_fn: Callable[[str], None] = print):
        self.cfg, self.opt_cfg, self.dataset = cfg, opt_cfg, dataset
        self.rules = rules
        self.ckpt_dir, self.save_every = ckpt_dir, save_every
        self.log_every, self.log = log_every, log_fn
        self.monitor = StragglerMonitor()
        self.checkpointer = ckpt.AsyncCheckpointer()
        self.step_fn = jax.jit(make_train_step(
            cfg, opt_cfg, rules=rules, grad_accum=grad_accum,
            compress_grads=compress_grads), donate_argnums=(0,))
        self.state = init_state(cfg, opt_cfg, jax.random.key(seed),
                                compress_grads=compress_grads)
        self.step = 0
        self.history: list = []
        if ckpt_dir:
            self._maybe_resume()

    # -- fault tolerance -------------------------------------------------
    def _maybe_resume(self):
        path = ckpt.latest_step_dir(self.ckpt_dir)
        if path is None:
            return
        like = jax.tree.map(np.asarray, self.state)
        self.state, extra = ckpt.restore(path, like)
        self.step = int(extra["step"])
        self.dataset.load_state_dict(extra["data"])
        self.log(f"[trainer] resumed from {path} at step {self.step}")

    def save(self):
        if not self.ckpt_dir:
            return
        path = os.path.join(self.ckpt_dir, f"step_{self.step:08d}")
        self.checkpointer.save(
            path, self.state,
            extra={"step": self.step, "data": self.dataset.state_dict()})

    # -- loop --------------------------------------------------------------
    def run(self, num_steps: int) -> Dict[str, Any]:
        it = iter(self.dataset)
        last_metrics: Dict[str, Any] = {}
        for _ in range(num_steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            # repro-lint: disable=host-sync — step timing needs the sync
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self.monitor.observe(dt)
            self.step += 1
            if slow:
                self.log(f"[straggler] step {self.step} took {dt:.3f}s "
                         f"(ewma {self.monitor.ewma:.3f}s)")
            if self.step % self.log_every == 0:
                last_metrics = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": self.step, **last_metrics,
                                     "dt": dt})
                self.log(f"[train] step {self.step} "
                         f"loss {last_metrics['loss']:.4f} dt {dt*1e3:.1f}ms")
            if self.save_every and self.step % self.save_every == 0:
                self.save()
        if self.ckpt_dir:
            self.save()
            self.checkpointer.wait()
        return last_metrics
