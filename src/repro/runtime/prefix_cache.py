"""Prefix-sharing KV cache: a radix tree over page-aligned token chunks.

The serving-level continuation of the paper's shared-memory argument
(PAPER.md, Sec. II-C): Voltra wins its temporal-utilization gain by letting
competing consumers dynamically (re)allocate ONE physical memory instead of
each holding a private copy. Here the competing consumers are *requests*:
production traffic ("millions of users") overlaps heavily — shared system
prompts, few-shot templates, multi-turn history — and without sharing,
every request recomputes and privately stores the KV of the same prefix.

Structure
---------
* **Key** = the request's token ids, chunked into page-size-aligned pieces.
  Each radix node holds exactly one full chunk (``page_size`` token ids)
  and the physical page storing that chunk's KV in every layer's pool.
  Page-aligned chunking means a radix hit IS a block-table entry: matched
  pages are written verbatim into the request's table, no copying.
* **Refcounts** live in ``kv_cache.PageAllocator``: the tree holds one pin
  (+1 ref) per cached page; each live table that reuses the page holds one
  more. Pages whose only reference is the tree's pin are *idle* —
  evictable but still instantly matchable (the hit path for a request
  arriving after its twin finished).
* **Copy-on-write**: a request that diverges *inside* a cached page (the
  shared tokens end mid-page) must not write its own suffix KV into the
  shared physical page. ``match()`` reports the partial hit; the engine
  copies the cached page into a fresh private one on device and prefills
  only the divergent tail (``serving.PagedServingEngine.submit``).
* **Eviction**: ``evict(n)`` releases idle pages in LRU order, leaves
  first (an inner node may not outlive its children, or a later match
  would walk across a freed page). The engine calls it when the free list
  runs dry, BEFORE falling back to preempting a live request — dropping
  an idle cached page costs one future re-prefill at most, preemption
  costs a guaranteed one.
* **Host tier**: with the two-tier hierarchy on (``runtime/host_tier.py``)
  idle pages *demote* instead of evicting: the node stays in the tree but
  ``page`` becomes None and ``host`` holds the host-store handle of the
  page's KV. A host node is still matchable — ``match()`` walks through
  it (page placeholder ``-1``) and reports the node path so the engine
  can promote (H2D) instead of re-prefilling. Demotion carries NO
  leaf-first constraint (the node keeps its place in the tree), so any
  idle device node may demote, in LRU order (``demotable``).

Host-side only (no jax): physical page ids in, physical page ids out.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.runtime.kv_cache import PageAllocator
from repro.runtime.trace import NULL_TRACER, Tracer

Chunk = Tuple[int, ...]


class _Node:
    __slots__ = ("chunk", "page", "parent", "children", "last_used", "host")

    def __init__(self, chunk: Optional[Chunk], page: int,
                 parent: Optional["_Node"]):
        self.chunk = chunk              # None only for the root
        self.page = page                # physical page id; None = demoted
        self.parent = parent
        self.children: Dict[Chunk, _Node] = {}
        self.last_used = 0
        self.host = None                # host-store handle when demoted


@dataclasses.dataclass
class PrefixMatch:
    """Result of a longest-prefix lookup.

    ``pages`` are full-page hits in block order (reusable verbatim in the
    block table). ``partial_page``/``partial_tokens`` describe a hit that
    ends inside a cached page: the first ``partial_tokens`` rows of
    ``partial_page`` hold valid KV, the engine must copy-on-write before
    prefilling past them. ``tokens`` counts every matched token.

    With the host tier on, a matched node may be host-resident: its entry
    in ``pages`` is the ``-1`` placeholder and ``path`` (the full-page
    node chain, one node per ``pages`` entry) carries the node so the
    engine can promote it back to a device page before use."""
    pages: List[int]
    tokens: int = 0
    partial_page: Optional[int] = None
    partial_tokens: int = 0
    # deepest matched node, for commit()'s LRU touch (internal)
    node: Optional[_Node] = None
    # full-page node chain, parallel to ``pages`` (internal)
    path: List[_Node] = dataclasses.field(default_factory=list)


class PrefixCache:
    """Radix tree mapping page-aligned token-id chunks -> physical pages."""

    def __init__(self, alloc: PageAllocator, *,
                 tracer: Optional[Tracer] = None):
        self.alloc = alloc
        self.trace = tracer if tracer is not None else NULL_TRACER
        self.page_size = alloc.page_size
        self.root = _Node(None, -1, None)
        self._by_page: Dict[int, _Node] = {}
        self._clock = 0
        # telemetry (lifetime counters; engine exports them)
        self.lookups = 0
        self.lookup_tokens = 0
        self.hits = 0                   # lookups with >= 1 matched token
        self.hit_tokens = 0             # tokens served from cache
        self.full_page_hits = 0         # pages reused without any copy
        self.partial_hits = 0           # matches ending inside a page (CoW)
        self.inserted_pages = 0
        self.evicted_pages = 0
        self.host_nodes = 0             # demoted (host-resident) nodes

    # -- queries ----------------------------------------------------------
    @property
    def cached_pages(self) -> int:
        return len(self._by_page)

    def _chunks(self, tokens: Sequence[int]) -> Iterable[Chunk]:
        ps = self.page_size
        for i in range(0, len(tokens) - ps + 1, ps):
            yield tuple(tokens[i:i + ps])

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        while node is not self.root:
            node.last_used = self._clock
            node = node.parent

    # -- match -------------------------------------------------------------
    def match(self, tokens: Sequence[int], *,
              max_tokens: Optional[int] = None) -> PrefixMatch:
        """Longest prefix of ``tokens`` present in the tree, in whole
        pages plus at most one partial page. ``max_tokens`` caps the match
        (the engine passes len-1 so at least one token is left to prefill
        — prefill must produce the next-token logits).

        Pure lookup: neither telemetry nor LRU state moves. The caller
        commits the match only once it is actually USED (commit()), so a
        rejected admission retried every scheduler tick doesn't inflate
        hit rates or keep a stalled request's prefix artificially hot."""
        with self.trace.span("match", tid="prefix"):
            return self._match(tokens, max_tokens=max_tokens)

    def _match(self, tokens: Sequence[int], *,
               max_tokens: Optional[int] = None) -> PrefixMatch:
        ps = self.page_size
        limit = len(tokens) if max_tokens is None else min(len(tokens),
                                                           max_tokens)
        node = self.root
        pages: List[int] = []
        path: List[_Node] = []
        i = 0
        while limit - i >= ps:
            child = node.children.get(tuple(tokens[i:i + ps]))
            if child is None:
                break
            # host-resident node: still a hit — placeholder page, the
            # engine promotes it (or truncates the match there).
            pages.append(child.page if child.page is not None else -1)
            path.append(child)
            node = child
            i += ps
        # divergence inside the next page: longest common prefix against
        # any child chunk (> 0 tokens) is still reusable KV, via CoW.
        # Device children only — a partial hit is consumed by an on-device
        # page copy, which a demoted page cannot serve.
        best_node: Optional[_Node] = None
        best_p = 0
        if limit > i:
            want = tuple(tokens[i:min(i + ps, limit)])
            for chunk, child in node.children.items():
                if child.page is None:
                    continue
                p = 0
                for a, b in zip(want, chunk):
                    if a != b:
                        break
                    p += 1
                if p > best_p:
                    best_p, best_node = p, child
        matched = i + best_p
        if best_node is not None:
            return PrefixMatch(pages, matched, best_node.page, best_p,
                               node=best_node, path=path)
        return PrefixMatch(pages, matched,
                           node=node if pages else None, path=path)

    def commit(self, m: PrefixMatch, total_tokens: int) -> None:
        """Record that a match() result was used to admit a request of
        ``total_tokens`` prompt tokens: bump the hit/lookup telemetry
        (misses count too — they are the hit-rate denominator) and touch
        the matched path's LRU clock, exactly once per admission."""
        self.lookups += 1
        self.lookup_tokens += total_tokens
        if m.tokens:
            self.hits += 1
            self.hit_tokens += m.tokens
            self.full_page_hits += len(m.pages)
            if m.partial_page is not None:
                self.partial_hits += 1
        if m.node is not None:
            self._touch(m.node)

    def reset_hit_counters(self) -> None:
        """Zero the per-lookup telemetry (benchmarks call this after a
        cache-warming phase so the timed replay reports its own rates);
        tree contents and the lifetime insert/evict counters survive."""
        self.lookups = self.lookup_tokens = 0
        self.hits = self.hit_tokens = 0
        self.full_page_hits = self.partial_hits = 0

    # -- insert ------------------------------------------------------------
    def insert(self, tokens: Sequence[int], table: Sequence[int]) -> int:
        """Publish ``tokens``'s full pages into the tree. ``table`` is the
        owner's block table; block ``j`` holds tokens ``[j*ps, (j+1)*ps)``.
        Pages already represented by an existing node are skipped (the
        owner keeps its private copy; future matches use the incumbent).
        Newly inserted pages are pinned in the allocator. Returns the
        number of pages inserted."""
        node = self.root
        added = 0
        for j, chunk in enumerate(self._chunks(tokens)):
            child = node.children.get(chunk)
            if child is None:
                page = table[j]
                if self.alloc.is_pinned(page):
                    # already in the tree under another path — a page can
                    # carry only one pin, and re-keying it would alias two
                    # token histories onto one physical page.
                    break
                child = _Node(chunk, page, node)
                node.children[chunk] = child
                self._by_page[page] = child
                self.alloc.cache_pin(page)
                added += 1
            node = child
        if added:
            self._touch(node)
            self.inserted_pages += added
            if self.trace:
                self.trace.instant("insert", tid="prefix",
                                   args={"pages": added})
        return added

    # -- host tier: demote / promote ---------------------------------------
    def demotable(self, protect: Optional[Set[int]] = None) -> List["_Node"]:
        """Idle device nodes (refcount == pin only), LRU first. Unlike
        eviction there is NO leaf-first constraint: a demoted node keeps
        its place in the tree (host nodes stay matchable), so an inner
        node may demote while its children stay on device."""
        protect = protect or set()
        out = [n for n in self._by_page.values()
               if n.page not in protect and self.alloc.ref(n.page) == 1]
        out.sort(key=lambda n: n.last_used)
        return out

    def demotable_count(self, protect: Optional[Set[int]] = None) -> int:
        return len(self.demotable(protect))

    def demote_node(self, node: _Node, handle) -> int:
        """Move ``node`` to the host tier: drop its pin (freeing the
        device page) and remember the host-store ``handle``. The caller
        must have dispatched the page-content gather BEFORE calling this
        (gather-then-free is safe under JAX dispatch ordering). Returns
        the freed page id."""
        page = node.page
        assert page is not None and node.host is None
        del self._by_page[page]
        node.page = None
        node.host = handle
        self.host_nodes += 1
        became_free = self.alloc.cache_unpin(page)
        assert became_free, "demoted an idle page that was still referenced"
        return page

    def promote_node(self, node: _Node, page: int) -> None:
        """Re-attach a host-resident node to device ``page`` (allocated
        pinned by the caller via ``PageAllocator.alloc_pinned_page``; the
        caller also scatters the page contents back)."""
        assert node.page is None and node.host is not None
        assert self.alloc.is_pinned(page)
        node.page = page
        node.host = None
        self._by_page[page] = node
        self.host_nodes -= 1

    # -- eviction ----------------------------------------------------------
    def _evictable(self, protect: Set[int]) -> List[_Node]:
        """Idle leaves (refcount == pin only, no children), LRU first."""
        out = [n for n in self._by_page.values()
               if not n.children and n.page not in protect
               and self.alloc.ref(n.page) == 1]
        out.sort(key=lambda n: n.last_used)
        return out

    def evictable_count(self, protect: Optional[Set[int]] = None) -> int:
        """How many pages evict() could free at most, honoring leaf-first
        order (an idle inner node whose subtree holds an in-use page can
        never be reached) — a dry run, nothing moves. Callers use it to
        skip an eviction that cannot cover their deficit anyway: flushing
        still-matchable prefixes for an admission that gets rejected
        regardless is pure loss."""
        protect = protect or set()
        removed: Set[int] = set()
        progress = True
        while progress:
            progress = False
            for node in self._by_page.values():
                if (node.page in removed or node.page in protect
                        or self.alloc.ref(node.page) != 1):
                    continue
                if any(c.page not in removed
                       for c in node.children.values()):
                    continue
                removed.add(node.page)
                progress = True
        return len(removed)

    def evict(self, n_pages: int,
              protect: Optional[Set[int]] = None) -> int:
        """Free up to ``n_pages`` pages by unpinning idle cached pages in
        LRU order, leaves first (evicting an inner node would orphan its
        children's KV mid-path). ``protect`` shields pages the caller is
        about to reuse (a match taken but not yet refcounted). Returns the
        number of pages actually freed."""
        protect = protect or set()
        freed = 0
        with self.trace.span("evict", tid="prefix"):
            while freed < n_pages:
                leaves = self._evictable(protect)
                if not leaves:
                    break
                for node in leaves:
                    if freed >= n_pages:
                        break
                    self._drop(node)
                    freed += 1
                    self.evicted_pages += 1
        return freed

    def _drop(self, node: _Node) -> None:
        assert not node.children
        del node.parent.children[node.chunk]
        del self._by_page[node.page]
        became_free = self.alloc.cache_unpin(node.page)
        assert became_free, "evicted an idle page that was still referenced"

    #: Every key ``stats()`` returns — the engine's ``prefix_stats``
    #: zero-fills these when sharing is off so metric / CSV key sets
    #: never depend on configuration.
    STAT_KEYS = (
        "lookups", "hits", "hit_rate", "hit_tokens", "shared_token_frac",
        "full_page_hits", "partial_hits", "inserted_pages",
        "evicted_pages", "cached_pages", "host_nodes")

    @staticmethod
    def zero_stats() -> Dict[str, float]:
        return {k: 0.0 for k in PrefixCache.STAT_KEYS}

    def stats(self) -> Dict[str, float]:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "hit_tokens": self.hit_tokens,
            "shared_token_frac": (self.hit_tokens / self.lookup_tokens
                                  if self.lookup_tokens else 0.0),
            "full_page_hits": self.full_page_hits,
            "partial_hits": self.partial_hits,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "cached_pages": self.cached_pages,
            "host_nodes": self.host_nodes,
        }
