"""Draft-token proposers for speculative decoding.

The verify step (``PagedServingEngine(spec_k=K)``) multiplies decode's
arithmetic intensity by the number of query rows it scores per page sweep
— the serving-level analogue of the paper's utilization argument (keep the
PEs fed at the SAME memory traffic). But it only pays off when the drafted
rows actually match what the target policy would have emitted, so a
drafter must be cheap relative to the target model and must hit on the
traffic that dominates production serving: templated prompts, few-shot
scaffolds, code, and the repetitive spans models themselves emit.

Two proposers share one interface (``propose(rid, ctx, k)`` -> up to k
token ids, ``drop(rid)`` on request finish/eviction, ``kind`` for
telemetry), both DETERMINISTIC — greedy proposals make the draft
distribution a point mass, so the engine's rejection-sampling acceptance
``u < min(1, p(x)/q(x))`` reduces to ``u < p(x)`` (and to exact-greedy
prefix matching at temperature 0; see ``runtime/sampling.py``):

* ``NgramDrafter`` / ``ngram_propose`` — prompt-lookup drafting (PLD /
  n-gram speculation): no second model, no extra parameters — the
  request's OWN context is the draft model. The longest suffix n-gram of
  the context that occurred earlier is located (most recent occurrence
  wins: recency tracks the current phrase distribution better than
  frequency at these context sizes) and the tokens that followed that
  occurrence are proposed verbatim. Host-side only, stateless.

* ``DraftModelDrafter`` — a small second model (any attention-only
  config from ``src/repro/configs/``) greedy-decodes k draft tokens,
  kept in sync with each request's context through its OWN single-slot
  paged KV cache: per step it truncates to the longest common prefix of
  its cached tokens and the new context (rejected drafts roll back,
  accepted ones are already cached), ingests the context delta in
  power-of-two multi-token decode blocks, then autoregressively drafts.
  Degrades to no-draft (empty list) instead of failing when its page
  pool can't host the context — the verify step then runs a plain
  single-token row, never a wrong token.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np


def ngram_propose(ctx: Sequence[int], k: int, *,
                  max_ngram: int = 3) -> List[int]:
    """Propose up to ``k`` draft tokens continuing ``ctx`` by prompt
    lookup: match the longest suffix n-gram (``max_ngram`` down to 1)
    against the rest of the context and return the tokens that followed
    its most recent earlier occurrence. Empty list = no match (the verify
    step then degrades to a plain single-token decode: one real row plus
    padding that is rolled back, never a wrong token)."""
    n_ctx = len(ctx)
    if k <= 0 or n_ctx < 2:
        return []
    ctx = list(ctx)
    for n in range(min(max_ngram, n_ctx - 1), 0, -1):
        suffix = ctx[n_ctx - n:]
        # scan right-to-left: the MOST RECENT earlier occurrence wins
        for start in range(n_ctx - n - 1, -1, -1):
            if ctx[start:start + n] == suffix:
                cont = ctx[start + n:start + n + k]
                if cont:
                    return cont
    return []


class NgramDrafter:
    """The prompt-lookup proposer behind the shared drafter interface.
    Stateless per request — ``drop`` is a no-op."""

    kind = "ngram"

    def __init__(self, max_ngram: int = 3):
        self.max_ngram = max_ngram

    def propose(self, rid: int, ctx: Sequence[int], k: int) -> List[int]:
        return ngram_propose(ctx, k, max_ngram=self.max_ngram)

    def drop(self, rid: int) -> None:
        pass

    def stats(self) -> Dict[str, float]:
        return {}


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class DraftModelDrafter:
    """Draft-model speculation: greedy-decode ``k`` continuation tokens
    from a small second model whose KV lives in a private paged cache
    (one slot, its own ``PageAllocator`` — completely separate from the
    serving engine's pool). See the module docstring for the sync
    protocol; the acceptance math is the engine's, unchanged — this
    class only has to propose deterministically.

    Requires an attention-only decoder config: windowed / recurrent /
    encoder-decoder draft models would need their own ring buffers or
    state slots, and the n-gram drafter already covers those stacks.
    """

    kind = "model"

    def __init__(self, cfg, params, *, page_size: int = 16,
                 num_pages: int = 128, max_len: int = 512,
                 max_ingest: int = 32, attn_impl: str = "gather"):
        import jax

        from repro.models import api
        from repro.models import transformer as tfm
        from repro.runtime.kv_cache import SCRATCH_PAGE, PageAllocator

        kinds = set(tfm.pattern_for(cfg))
        if not kinds <= set(api.PAGEABLE_KINDS):
            raise ValueError(
                f"draft-model drafter needs an attention-only decoder "
                f"(kinds within {sorted(api.PAGEABLE_KINDS)}); "
                f"{cfg.name!r} has {sorted(kinds)} — windowed/recurrent/"
                f"enc-dec draft models would need their own ring buffers "
                f"or state slots; use the n-gram drafter for those stacks")
        assert page_size >= 1 and page_size & (page_size - 1) == 0, \
            "page_size must be a power of two"
        cfg = dataclasses.replace(cfg, paged_attn_impl=attn_impl)
        self.cfg, self.params = cfg, params
        self.page_size = page_size
        self.max_len = -(-max_len // page_size) * page_size
        self.max_blocks = self.max_len // page_size
        self.max_ingest = max(1, _next_pow2(max_ingest))
        self._scratch = SCRATCH_PAGE
        self.alloc = PageAllocator(num_pages, page_size)
        # pool row 0 is the scratch page (padding rows land there)
        self.cache = api.paged_cache_init(cfg, 1, num_pages + 1, page_size)
        self._tables: Dict[int, np.ndarray] = {}   # rid -> device-row mirror
        self._toks: Dict[int, List[int]] = {}      # rid -> tokens in cache
        self._ntok: Dict[int, int] = {}            # rid -> allocator tokens
        self.proposed = 0
        self.ingested_tokens = 0
        self.decode_calls = 0
        self.pool_rejects = 0

        import jax.numpy as jnp

        def fn(params_, cache, table, toks, pos):
            logits, cache = api.decode_step(cfg, params_, cache, toks, pos,
                                            block_table=table)
            # (1, V) for T == 1, (1, T, V) for T > 1 — greedy either way
            out = jnp.argmax(logits[..., : cfg.vocab], -1)
            return cache, out.astype(jnp.int32)

        self._fn = jax.jit(fn)

    # -- paged-cache bookkeeping ------------------------------------------

    def _sync_row(self, rid: int) -> None:
        """Rebuild rid's host table row from the allocator: real pages in
        block order, everything past them SCRATCH — so the padding rows
        of a power-of-two ingest block can only ever write scratch."""
        row = np.full((self.max_blocks,), self._scratch, np.int32)
        t = self.alloc.block_table(rid)
        row[: len(t)] = t
        self._tables[rid] = row

    def _drop_table(self, rid: int) -> None:
        if rid in self._tables:
            self.alloc.free_request(rid)
            del self._tables[rid]
        self._toks.pop(rid, None)
        self._ntok.pop(rid, None)

    def _evict_others(self, keep: int) -> bool:
        dropped = False
        for other in list(self._tables):
            if other != keep:
                self._drop_table(other)
                dropped = True
        return dropped

    def _ensure(self, rid: int, n_tokens: int) -> bool:
        """Cover ``n_tokens`` of rid's context with pages, evicting OTHER
        requests' draft caches under pressure (they re-ingest later;
        draft caches are pure accelerators). False = pool can't host even
        alone — the caller degrades to no-draft."""
        page = self.page_size
        if rid not in self._tables:
            got = self.alloc.allocate(rid, n_tokens)
            if got is None:
                if not self._evict_others(rid):
                    return False
                got = self.alloc.allocate(rid, n_tokens)
                if got is None:
                    return False
            self._ntok[rid] = n_tokens
            self._sync_row(rid)
            return True
        # ALWAYS advance through extend_to (one-page steps, its contract)
        # even when the pages already cover the target: extend_to is what
        # keeps the allocator's logical token count current, and a later
        # divergence rollback truncate_to()s against that count.
        while self._ntok[rid] < n_tokens:
            step = min(n_tokens, self._ntok[rid] + page)
            got = self.alloc.extend_to(rid, step)
            if got is None:
                if not self._evict_others(rid):
                    return False
                continue
            self._ntok[rid] = step
            if got:
                self._sync_row(rid)
        return True

    # -- the drafter interface --------------------------------------------

    def propose(self, rid: int, ctx: Sequence[int], k: int) -> List[int]:
        import jax
        import jax.numpy as jnp

        if k <= 0 or not ctx:
            return []
        ctx = list(ctx)
        L = len(ctx)
        if L + k >= self.max_len:
            return []                 # out of drafter context: degrade
        prev = self._toks.get(rid, [])
        common = 0
        for a, b in zip(prev, ctx):
            if a != b:
                break
            common += 1
        # keep at least the last context token un-ingested: its decode
        # row's logits seed the first draft
        have = min(common, L - 1)
        if prev:
            if have == 0:
                self._drop_table(rid)
            elif have < len(prev):
                # rejected drafts (or a resumed request that diverged):
                # disown whole pages past the keep point; stale rows
                # inside kept pages are overwritten by the re-ingest
                # below before any query can attend to them
                self.alloc.truncate_to(rid, have)
                self._ntok[rid] = have
                self._sync_row(rid)

        def run(block, pos):
            self.cache, out = self._fn(
                self.params, self.cache,
                jnp.asarray(self._tables[rid])[None, :],
                jnp.asarray(block), jnp.asarray([pos], jnp.int32))
            self.decode_calls += 1
            # repro-lint: disable=host-sync — host-side drafting by design
            return np.asarray(jax.device_get(out)).reshape(-1)

        # ingest the context delta in pow2-padded multi-token blocks
        # (bounded trace count; padding rows write only scratch)
        pending = ctx[have:]
        pos = have
        last_tok: Optional[int] = None
        while pending:
            real = min(len(pending), self.max_ingest)
            T = _next_pow2(real)
            if not self._ensure(rid, pos + real):
                self.pool_rejects += 1
                self._drop_table(rid)
                return []
            block = np.zeros((1, T), np.int32)
            block[0, :real] = pending[:real]
            out = run(block, pos)
            pos += real
            pending = pending[real:]
            self.ingested_tokens += real
            if not pending:
                last_tok = int(out[real - 1])
        drafts = [last_tok]
        # autoregressive greedy drafting; each draft's KV is cached so an
        # accepted draft is already ingested next step
        while len(drafts) < k:
            if not self._ensure(rid, pos + 1):
                self.pool_rejects += 1
                break
            out = run(np.asarray([[drafts[-1]]], np.int32), pos)
            pos += 1
            self.ingested_tokens += 1
            drafts.append(int(out[-1]))
        self._toks[rid] = ctx + drafts[:-1]
        self.proposed += len(drafts)
        return drafts

    def drop(self, rid: int) -> None:
        """Request finished / evicted: free its draft pages."""
        self._drop_table(rid)

    def stats(self) -> Dict[str, float]:
        return {
            "draft_proposed": float(self.proposed),
            "draft_ingested_tokens": float(self.ingested_tokens),
            "draft_decode_calls": float(self.decode_calls),
            "draft_pool_rejects": float(self.pool_rejects),
        }
