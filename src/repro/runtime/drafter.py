"""Model-free draft-token proposers for speculative decoding.

The verify step (``PagedServingEngine(spec_k=K)``) multiplies decode's
arithmetic intensity by the number of query rows it scores per page sweep
— the serving-level analogue of the paper's utilization argument (keep the
PEs fed at the SAME memory traffic). But it only pays off when the drafted
rows actually match what greedy decode would have emitted, so the drafter
must be cheap (it runs on the host, per live request, per step) and must
hit on the traffic that dominates production serving: templated prompts,
few-shot scaffolds, code, and the repetitive spans models themselves emit.

``ngram_propose`` is prompt-lookup drafting (PLD / n-gram speculation): no
second model, no extra parameters — the request's OWN context is the
draft model. The longest suffix n-gram of the context that occurred
earlier is located (most recent occurrence wins: recency tracks the
current phrase distribution better than frequency at these context sizes)
and the tokens that followed that occurrence are proposed verbatim.

Host-side only (no jax): token ids in, token ids out.
"""
from __future__ import annotations

from typing import List, Sequence


def ngram_propose(ctx: Sequence[int], k: int, *,
                  max_ngram: int = 3) -> List[int]:
    """Propose up to ``k`` draft tokens continuing ``ctx`` by prompt
    lookup: match the longest suffix n-gram (``max_ngram`` down to 1)
    against the rest of the context and return the tokens that followed
    its most recent earlier occurrence. Empty list = no match (the verify
    step then degrades to a plain single-token decode: one real row plus
    padding that is rolled back, never a wrong token)."""
    n_ctx = len(ctx)
    if k <= 0 or n_ctx < 2:
        return []
    ctx = list(ctx)
    for n in range(min(max_ngram, n_ctx - 1), 0, -1):
        suffix = ctx[n_ctx - n:]
        # scan right-to-left: the MOST RECENT earlier occurrence wins
        for start in range(n_ctx - n - 1, -1, -1):
            if ctx[start:start + n] == suffix:
                cont = ctx[start + n:start + n + k]
                if cont:
                    return cont
    return []
