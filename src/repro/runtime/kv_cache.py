"""Paged KV-cache bookkeeping: fixed-size pages, per-request block tables.

The software analogue of Voltra's dynamic shared-memory allocation
(PAPER.md): instead of giving every batch slot a dense ``max_len`` cache
lane ("separated, statically partitioned memory"), the KV pool is a flat
array of fixed-size pages, and each request owns exactly the pages its
live tokens need — allocated on demand as decode crosses page boundaries
and reclaimed the moment the request finishes. Utilization counters mirror
the paper's temporal-utilization measurement: live tokens over allocated
capacity, vs. the dense baseline's ``slots * max_len``.

This module is host-side only (no jax import): the allocator hands out
*physical page ids*; the device-side pools and gathers live in
``repro.models.api`` / ``repro.models.layers``, which consume the block
tables built here.

Page 0 is reserved as the scratch page: dead slots and beyond-allocation
prefill blocks are redirected there, so a finished request can never
scribble over a page that has been reclaimed and re-issued to a live
neighbor. Scratch contents are garbage by design and are always masked
out by ``kv_valid`` (= per-request token count) on the read side.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

SCRATCH_PAGE = 0


class PageAllocator:
    """Free-list page allocator with per-request block tables.

    ``num_pages`` counts *usable* pages; one extra scratch page (id 0) is
    implicit, so physical ids run 0..num_pages (inclusive) and the device
    pool must be sized ``num_pages + 1``.
    """

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 1 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list keeps the working set hot (ids 1..num_pages).
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._tables: Dict[int, List[int]] = {}   # rid -> physical pages
        self._tokens: Dict[int, int] = {}         # rid -> live token count
        self.peak_pages = 0                        # high-water mark
        self.alloc_events = 0                      # pages handed out, total

    # -- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def allocated_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def live_tokens(self) -> int:
        return sum(self._tokens.values())

    @property
    def live_requests(self) -> int:
        return len(self._tables)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (>= 1 page once admitted)."""
        return max(1, -(-n_tokens // self.page_size))

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    def block_table(self, rid: int) -> List[int]:
        return list(self._tables[rid])

    def tokens(self, rid: int) -> int:
        return self._tokens[rid]

    def utilization(self) -> float:
        """Live tokens over allocated page capacity (1.0 = no slack)."""
        cap = self.allocated_pages * self.page_size
        return self.live_tokens / cap if cap else 1.0

    # -- lifecycle --------------------------------------------------------
    def allocate(self, rid: int, n_tokens: int) -> Optional[List[int]]:
        """Admit ``rid`` with ``n_tokens`` live tokens. Returns its block
        table, or None (state unchanged) if the pool can't cover it."""
        assert rid not in self._tables, f"rid {rid} already admitted"
        need = self.pages_for(n_tokens)
        if need > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(need)]
        self._tables[rid] = pages
        self._tokens[rid] = n_tokens
        self.alloc_events += need
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return list(pages)

    def extend_to(self, rid: int, n_tokens: int) -> Optional[int]:
        """Grow ``rid`` to cover ``n_tokens`` tokens (allocate-on-demand).

        Returns the newly allocated physical page id if a page boundary was
        crossed, 0 if the current pages already cover it, or None if the
        pool is exhausted (state unchanged — caller preempts)."""
        assert rid in self._tables
        need = self.pages_for(n_tokens)
        have = len(self._tables[rid])
        assert need <= have + 1, "extend_to must grow by <= 1 page"
        if need <= have:
            self._tokens[rid] = max(self._tokens[rid], n_tokens)
            return 0
        if not self._free:
            return None
        page = self._free.pop()
        self._tables[rid].append(page)
        self._tokens[rid] = n_tokens
        self.alloc_events += 1
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return page

    def free_request(self, rid: int) -> int:
        """Reclaim every page of ``rid``. Returns the number reclaimed."""
        pages = self._tables.pop(rid)
        del self._tokens[rid]
        self._free.extend(reversed(pages))   # LIFO: reuse hottest first
        return len(pages)

    # -- invariants (cheap; used by tests and debug asserts) --------------
    def check_no_aliasing(self) -> None:
        """No physical page appears in two live block tables or in both a
        live table and the free list; scratch is never handed out."""
        seen: Dict[int, int] = {}
        for rid, pages in self._tables.items():
            for p in pages:
                assert p != SCRATCH_PAGE, f"rid {rid} holds scratch page"
                assert p not in seen, (
                    f"page {p} aliased by rids {seen[p]} and {rid}")
                seen[p] = rid
        for p in self._free:
            assert p not in seen, f"page {p} both free and owned"


@dataclasses.dataclass
class PoolStats:
    """Snapshot of pool utilization for benchmark/telemetry output."""
    page_size: int
    num_pages: int
    allocated_pages: int
    peak_pages: int
    live_tokens: int
    utilization: float
    dense_equiv_tokens: int    # what the dense engine would have reserved

    @staticmethod
    def of(alloc: PageAllocator, slots: int, max_len: int) -> "PoolStats":
        return PoolStats(
            page_size=alloc.page_size, num_pages=alloc.num_pages,
            allocated_pages=alloc.allocated_pages,
            peak_pages=alloc.peak_pages, live_tokens=alloc.live_tokens,
            utilization=alloc.utilization(),
            dense_equiv_tokens=slots * max_len)
