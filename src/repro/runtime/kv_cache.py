"""Paged KV-cache bookkeeping: fixed-size pages, per-request block tables,
refcounted sharing.

The software analogue of Voltra's dynamic shared-memory allocation
(PAPER.md): instead of giving every batch slot a dense ``max_len`` cache
lane ("separated, statically partitioned memory"), the KV pool is a flat
array of fixed-size pages, and each request owns exactly the pages its
live tokens need — allocated on demand as decode crosses page boundaries
and reclaimed the moment the request finishes. Utilization counters mirror
the paper's temporal-utilization measurement: live tokens over allocated
capacity, vs. the dense baseline's ``slots * max_len``.

Since PR 3 pages are **refcounted**, not unique-owner: several requests'
block tables may point at the same physical page (prefix sharing,
``runtime/prefix_cache.py``), and the prefix cache itself holds a pin
(+1 ref) on every page it keeps in its radix tree. A page returns to the
free list only when its refcount reaches zero — i.e. no live table and no
cache pin references it. The share/copy-on-write discipline (who may
*write* a page) is enforced one level up, in the serving engine: a page
is writable only while exactly one table holds it and it is not pinned.

This module is host-side only (no jax import): the allocator hands out
*physical page ids*; the device-side pools and gathers live in
``repro.models.api`` / ``repro.models.layers``, which consume the block
tables built here.

Page 0 is reserved as the scratch page: dead slots and beyond-allocation
prefill blocks are redirected there, so a finished request can never
scribble over a page that has been reclaimed and re-issued to a live
neighbor. Scratch contents are garbage by design and are always masked
out by ``kv_valid`` (= per-request token count) on the read side.

**Sliding-window tables** (hybrid stacks, ``local_attn`` layers): a table
may carry a *base-block offset* — logical blocks ``0 .. base-1`` have
slid entirely out of the attention window and their pages were recycled
(``release_prefix``), so the table holds only the live suffix and the
request's footprint stays O(window) pages while its logical length keeps
growing. ``allocate(..., base_blocks=)`` admits a long prompt with the
pre-window blocks never allocated at all.

**Host-resident tables** (two-tier KV hierarchy, ``runtime/host_tier.py``):
``demote(rid)`` moves a request's table into a third lifecycle class —
neither live nor freed — releasing its device pages while remembering the
token count and window base, so ``promote(rid)`` can later rebuild the
table from fresh pages and the engine can scatter the host-held page
contents back. The allocator only tracks the *bookkeeping* of the tier
(which rids are host-resident, how many pages they need back); the page
CONTENTS move through the engine's gather/scatter programs and the host
page store. ``check()`` verifies the host class stays disjoint from the
live tables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

SCRATCH_PAGE = 0


class PageAllocator:
    """Free-list page allocator with per-request block tables and per-page
    refcounts.

    ``num_pages`` counts *usable* pages; one extra scratch page (id 0) is
    implicit, so physical ids run 0..num_pages (inclusive) and the device
    pool must be sized ``num_pages + 1``.
    """

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 1 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        # LIFO free list keeps the working set hot (ids 1..num_pages).
        self._free: List[int] = list(range(num_pages, 0, -1))
        self._tables: Dict[int, List[int]] = {}   # rid -> physical pages
        self._tokens: Dict[int, int] = {}         # rid -> live token count
        self._base: Dict[int, int] = {}           # rid -> recycled lead blocks
        self._ref: Dict[int, int] = {}            # page -> refcount (>0)
        self._pinned: Set[int] = set()            # prefix-cache pins (+1 ref)
        # rid -> (tokens, base_blocks) for demoted (host-resident) tables:
        # no device pages, but not forgotten — promote() rebuilds the table
        self._host: Dict[int, Tuple[int, int]] = {}
        self.peak_pages = 0                        # high-water mark
        self.alloc_events = 0                      # pages handed out, total
        self.share_events = 0                      # table refs to shared pages
        self.demote_events = 0                     # tables demoted to host
        self.promote_events = 0                    # tables promoted back

    # -- queries ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def allocated_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def cached_idle_pages(self) -> int:
        """Pages held *only* by the prefix cache (evictable on pressure)."""
        return sum(1 for p in self._pinned if self._ref[p] == 1)

    @property
    def live_tokens(self) -> int:
        """Tokens resident in live pages (a windowed table's recycled
        lead blocks no longer hold tokens, so they don't count)."""
        return sum(t - self._base.get(r, 0) * self.page_size
                   for r, t in self._tokens.items())

    @property
    def live_requests(self) -> int:
        return len(self._tables)

    def ref(self, page: int) -> int:
        """Current refcount of ``page`` (0 = free)."""
        return self._ref.get(page, 0)

    def is_pinned(self, page: int) -> bool:
        return page in self._pinned

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (>= 1 page once admitted)."""
        return max(1, -(-n_tokens // self.page_size))

    def can_admit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    def block_table(self, rid: int) -> List[int]:
        """Live pages of ``rid`` in block order. For a windowed table this
        is the suffix starting at logical block ``base_blocks(rid)``."""
        return list(self._tables[rid])

    def base_blocks(self, rid: int) -> int:
        """Logical blocks recycled off the front of ``rid``'s table
        (sliding-window page recycling); 0 for ordinary tables."""
        return self._base.get(rid, 0)

    def tokens(self, rid: int) -> int:
        return self._tokens[rid]

    def utilization(self) -> float:
        """Live tokens over allocated page capacity (1.0 = no slack; can
        EXCEED 1.0 once prefix sharing lets several requests' logical
        tokens occupy one physical page)."""
        cap = self.allocated_pages * self.page_size
        return self.live_tokens / cap if cap else 1.0

    # -- lifecycle --------------------------------------------------------
    def _pop_free(self) -> int:
        page = self._free.pop()
        self._ref[page] = 1
        self.alloc_events += 1
        return page

    def _decref(self, page: int) -> bool:
        """Drop one reference; returns True if the page became free."""
        n = self._ref[page] - 1
        if n:
            self._ref[page] = n
            return False
        del self._ref[page]
        self._free.append(page)
        return True

    def allocate(self, rid: int, n_tokens: int,
                 base_blocks: int = 0) -> Optional[List[int]]:
        """Admit ``rid`` with ``n_tokens`` live tokens. Returns its block
        table, or None (state unchanged) if the pool can't cover it.

        ``base_blocks`` > 0 admits a sliding-window table whose first
        ``base_blocks`` logical blocks already sit entirely below the
        attention window (a prompt longer than the window): those pages
        are never allocated, so admission costs O(window) pages, not
        O(prompt)."""
        if base_blocks == 0:
            return self.allocate_shared(rid, n_tokens, [])
        assert rid not in self._tables, f"rid {rid} already admitted"
        need = self.pages_for(n_tokens) - base_blocks
        assert need >= 1, "base_blocks must leave at least one live block"
        if need > len(self._free):
            return None
        pages = [self._pop_free() for _ in range(need)]
        self._tables[rid] = pages
        self._tokens[rid] = n_tokens
        self._base[rid] = base_blocks
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return list(pages)

    def release_prefix(self, rid: int, n_blocks: int) -> int:
        """Sliding-window page recycling: drop ``rid``'s reference to its
        first ``n_blocks`` live table entries — blocks that have slid
        entirely below the attention window and can never be read again.
        The table's logical indexing is preserved by advancing the base
        offset (``base_blocks``), so logical block j keeps meaning
        absolute positions ``[j*page, (j+1)*page)``. Returns the number
        of pages that actually became free."""
        table = self._tables[rid]
        assert 0 <= n_blocks < len(table), \
            f"release_prefix({n_blocks}) must keep >= 1 of {len(table)} blocks"
        freed = 0
        for p in table[:n_blocks]:
            freed += self._decref(p)
        del table[:n_blocks]
        self._base[rid] = self._base.get(rid, 0) + n_blocks
        return freed

    def allocate_shared(self, rid: int, n_tokens: int,
                        shared: List[int]) -> Optional[List[int]]:
        """Admit ``rid`` reusing ``shared`` (already-allocated prefix pages,
        in block order) and allocating fresh pages for the remainder.
        Returns the block table ``shared + fresh`` with every shared page's
        refcount incremented, or None (state unchanged — no refs taken) if
        the free list can't cover the fresh part."""
        assert rid not in self._tables, f"rid {rid} already admitted"
        need = self.pages_for(n_tokens)
        assert len(shared) <= need, "shared prefix longer than the request"
        fresh_n = need - len(shared)
        if fresh_n > len(self._free):
            return None
        for p in shared:
            assert p in self._ref, f"shared page {p} is not allocated"
            self._ref[p] += 1
        self.share_events += len(shared)
        pages = list(shared) + [self._pop_free() for _ in range(fresh_n)]
        self._tables[rid] = pages
        self._tokens[rid] = n_tokens
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return list(pages)

    def extend_to(self, rid: int, n_tokens: int) -> Optional[int]:
        """Grow ``rid`` to cover ``n_tokens`` tokens (allocate-on-demand).

        Returns the newly allocated physical page id if a page boundary was
        crossed, 0 if the current pages already cover it, or None if the
        pool is exhausted (state unchanged — caller evicts or preempts)."""
        assert rid in self._tables
        need = self.pages_for(n_tokens) - self._base.get(rid, 0)
        have = len(self._tables[rid])
        assert need <= have + 1, "extend_to must grow by <= 1 page"
        if need <= have:
            self._tokens[rid] = max(self._tokens[rid], n_tokens)
            return 0
        if not self._free:
            return None
        page = self._pop_free()
        self._tables[rid].append(page)
        self._tokens[rid] = n_tokens
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return page

    def truncate_to(self, rid: int, n_tokens: int) -> int:
        """Roll ``rid`` back to ``n_tokens`` live tokens (speculative-decode
        rejection: drafted tokens past the accept point are disowned).
        Drops the request's reference to every WHOLE page past the ones
        ``n_tokens`` needs — refcount/CoW-safe: a dropped page that is
        still shared or cache-pinned survives its other references, only
        this table's claim goes. Rows of the kept tail page beyond the
        accept point are left as garbage by design (always masked out by
        the per-request length on the read side, overwritten by the next
        decode write). Returns the number of table entries dropped."""
        assert rid in self._tables
        assert 0 < n_tokens <= self._tokens[rid], \
            f"truncate_to({n_tokens}) must shrink rid {rid} " \
            f"({self._tokens[rid]} tokens)"
        table = self._tables[rid]
        keep = self.pages_for(n_tokens) - self._base.get(rid, 0)
        assert keep >= 1, \
            "truncate_to cannot roll a windowed table back past its base"
        dropped = len(table) - keep
        for p in reversed(table[keep:]):   # LIFO: reuse hottest first
            self._decref(p)
        del table[keep:]
        self._tokens[rid] = n_tokens
        return dropped

    def replace_page(self, rid: int, block: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write swap: give ``rid`` a fresh private page in table
        slot ``block``, dropping its reference to the page currently there.
        Returns (old_page, new_page) — the caller must copy the device
        contents old -> new and update the device table — or None if no
        free page is available (state unchanged)."""
        table = self._tables[rid]
        assert 0 <= block < len(table)
        if not self._free:
            return None
        old = table[block]
        new = self._pop_free()
        table[block] = new
        self._decref(old)
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return old, new

    def free_request(self, rid: int) -> int:
        """Drop ``rid``'s reference to every page of its table. Returns the
        number of pages that actually became free (shared / cache-pinned
        pages survive their other references)."""
        pages = self._tables.pop(rid)
        del self._tokens[rid]
        self._base.pop(rid, None)
        freed = 0
        for p in reversed(pages):       # LIFO: reuse hottest first
            freed += self._decref(p)
        return freed

    # -- host tier (two-tier KV hierarchy) ---------------------------------
    def host_resident(self, rid) -> bool:
        return rid in self._host

    def host_tokens(self, rid) -> int:
        return self._host[rid][0]

    def host_base_blocks(self, rid) -> int:
        return self._host[rid][1]

    def host_pages_needed(self, rid) -> int:
        """Device pages ``promote(rid)`` would have to allocate."""
        tokens, base = self._host[rid]
        return self.pages_for(tokens) - base

    def demote(self, rid) -> List[int]:
        """Move ``rid``'s table to the host-resident class: drop its
        reference to every device page (shared / cache-pinned pages
        survive their other references) while remembering the token count
        and window base so ``promote`` can rebuild it. Returns the old
        block table — the caller must have GATHERED those pages' contents
        to a host copy before the freed pages are rewritten (JAX dispatch
        ordering makes gather-then-free safe: the gather was dispatched
        against the pre-free pool value)."""
        assert rid not in self._host, f"rid {rid} already host-resident"
        pages = self._tables.pop(rid)
        tokens = self._tokens.pop(rid)
        base = self._base.pop(rid, 0)
        self._host[rid] = (tokens, base)
        for p in reversed(pages):       # LIFO: reuse hottest first
            self._decref(p)
        self.demote_events += 1
        return pages

    def promote(self, rid) -> Optional[List[int]]:
        """Rebuild a host-resident table from fresh device pages. Returns
        the new block table (the caller scatters the host page contents
        into it and republishes the device row), or None (state unchanged,
        rid stays host-resident) if the free list can't cover it. Shared
        prefix pages are NOT re-shared: the promoted table is fully
        private — correct, slightly wasteful, and CoW-free."""
        tokens, base = self._host[rid]
        need = self.pages_for(tokens) - base
        if need > len(self._free):
            return None
        del self._host[rid]
        pages = [self._pop_free() for _ in range(need)]
        self._tables[rid] = pages
        self._tokens[rid] = tokens
        if base:
            self._base[rid] = base
        self.promote_events += 1
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return list(pages)

    def drop_host(self, rid) -> None:
        """Forget a host-resident table (the request finished or was
        abandoned while swapped out)."""
        del self._host[rid]

    def alloc_pinned_page(self) -> Optional[int]:
        """Allocate one page whose ONLY reference is a prefix-cache pin
        (no table occurrence) — the target of a host-resident radix
        node's promotion. None if the free list is dry."""
        if not self._free:
            return None
        page = self._pop_free()         # ref = 1 ...
        self._pinned.add(page)          # ... and that 1 is the pin
        self.peak_pages = max(self.peak_pages, self.allocated_pages)
        return page

    # -- prefix-cache pins -------------------------------------------------
    def cache_pin(self, page: int) -> None:
        """The prefix cache keeps ``page`` alive (+1 ref) while it sits in
        the radix tree, so it survives its last owner finishing."""
        assert page in self._ref, f"cannot pin free page {page}"
        assert page not in self._pinned, f"page {page} already pinned"
        self._ref[page] += 1
        self._pinned.add(page)

    def cache_unpin(self, page: int) -> bool:
        """Drop the prefix-cache pin (eviction). Returns True if the page
        became free (no live table was still referencing it)."""
        self._pinned.discard(page)
        return self._decref(page)

    # -- invariants (cheap; used by tests and debug asserts) --------------
    def check(self) -> None:
        """Shared-page-aware pool invariant: every allocated page's
        refcount equals its table occurrences plus its cache pin; no page
        is both free and referenced; scratch is never handed out; free +
        allocated covers exactly the usable pages."""
        occurrences: Dict[int, int] = {}
        for rid, pages in self._tables.items():
            assert len(set(pages)) == len(pages), \
                f"rid {rid} table repeats a page"
            for p in pages:
                assert p != SCRATCH_PAGE, f"rid {rid} holds scratch page"
                occurrences[p] = occurrences.get(p, 0) + 1
        free = set(self._free)
        assert len(free) == len(self._free), "free list repeats a page"
        for p, n in self._ref.items():
            assert p not in free, f"page {p} both free and referenced"
            want = occurrences.get(p, 0) + (1 if p in self._pinned else 0)
            assert n == want, (
                f"page {p}: refcount {n} != {occurrences.get(p, 0)} table "
                f"refs + {int(p in self._pinned)} pin")
        for p in occurrences:
            assert p in self._ref, f"page {p} in a table but not allocated"
        for p in self._pinned:
            assert p in self._ref, f"pinned page {p} not allocated"
        for rid, base in self._base.items():
            assert rid in self._tables and base >= 0, \
                f"window base for dead rid {rid}"
            assert self._tokens[rid] >= base * self.page_size, \
                f"rid {rid}: base {base} past its {self._tokens[rid]} tokens"
        for rid, (tokens, base) in self._host.items():
            assert rid not in self._tables, \
                f"rid {rid} is both live and host-resident"
            assert tokens >= 1 and base >= 0, \
                f"host rid {rid}: bad record ({tokens}, {base})"
            assert tokens >= base * self.page_size, \
                f"host rid {rid}: base {base} past its {tokens} tokens"
            assert self.pages_for(tokens) - base >= 1, \
                f"host rid {rid}: promotion would rebuild an empty table"
        assert len(free) + len(self._ref) == self.num_pages
        assert SCRATCH_PAGE not in free and SCRATCH_PAGE not in self._ref

    def check_no_aliasing(self) -> None:
        """Pre-sharing spelling of ``check()`` (kept for callers that
        predate refcounting): additionally asserts nothing is shared."""
        self.check()
        for p, n in self._ref.items():
            pin = 1 if p in self._pinned else 0
            assert n - pin <= 1, f"page {p} shared by {n - pin} tables"


@dataclasses.dataclass
class PoolStats:
    """Snapshot of pool utilization for benchmark/telemetry output."""
    page_size: int
    num_pages: int
    allocated_pages: int
    peak_pages: int
    live_tokens: int
    utilization: float
    dense_equiv_tokens: int    # what the dense engine would have reserved
    cached_idle_pages: int = 0  # prefix-cache-only pages (evictable)
    shared_page_refs: int = 0   # table refs served by sharing, lifetime

    @staticmethod
    def of(alloc: PageAllocator, slots: int, max_len: int) -> "PoolStats":
        return PoolStats(
            page_size=alloc.page_size, num_pages=alloc.num_pages,
            allocated_pages=alloc.allocated_pages,
            peak_pages=alloc.peak_pages, live_tokens=alloc.live_tokens,
            utilization=alloc.utilization(),
            dense_equiv_tokens=slots * max_len,
            cached_idle_pages=alloc.cached_idle_pages,
            shared_page_refs=alloc.share_events)
