"""Host-RAM KV page tier with async copy streams (two-tier memory
hierarchy for the paged serving engine).

The serving-scale reproduction of Voltra's shared-memory streamers
(PAPER.md): the paper's temporal-utilization win comes from *mixed-grained
hardware prefetching* plus dynamic allocation — data is staged into the
shared memory ahead of the consumer instead of fetched on demand. Here the
"shared memory" is the device page pool and the backing store is host RAM:
cold pages DEMOTE to a NumPy-backed host store instead of being destroyed,
and the copy stream prefetches them back ahead of the decode sweep, so a
working set much larger than the device pool serves with zero output
change (benchmarks/serve_bench.py ``--scenario oversubscribe``).

Three demotion sources (wired in ``runtime/serving.py``):

* **idle prefix-cache pages** — demoted before LRU-evicting; a radix hit
  on a host-resident node promotes (H2D) instead of re-prefilling;
* **preempted requests** — their whole table (and a hybrid stack's
  recurrent state slots) swaps out request-granularly; resume = promote +
  scatter + state import, NO re-prefill;
* **slid-out window pages** — archived (capped) for future hybrid prefix
  caching rather than destroyed outright.

The streamer is mixed-grained like the paper's: *page-granular* readahead
(individual radix-node pages for pending prompts) and *request-granular*
bulk restore (a preempted request's whole swap set), both started one
scheduler tick ahead (``Scheduler.tick`` -> ``engine.prefetch_pending``)
so the H2D copies overlap the current decode step.

Copy-stream contract (what the streamer may and may not reorder):

* D2H copies start at demotion time (``jax.Array.copy_to_host_async``)
  and are FINALIZED at most one decode tick later (``drain()`` — the
  engine calls it once per ``step()``, mirroring the one-host-sync
  contract) or on first use, whichever comes first. Gather-then-free is
  safe without a sync: the gather was dispatched against the pre-free
  pool value, and JAX's dispatch ordering keeps that buffer alive until
  the copy completes.
* H2D prefetches (``jax.device_put``) may start any tick and complete in
  any order; a consumer that finds its copy not yet started pays a
  demand fetch (counted as a copy-stream stall).
* The stream never reorders *visibility*: a handle is only consumed via
  ``take``/``get``, which always returns the complete blob.

Host-side module: the only jax calls are ``device_put`` and the async
D2H finalization — no tracing, no kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.runtime.trace import NULL_TRACER, Tracer


def _tree_nbytes(tree) -> int:
    return sum(leaf.nbytes for leaf in jax.tree.leaves(tree))


def _finalize(tree):
    """Resolve a pending D2H tree to host numpy leaves."""
    return jax.tree.map(lambda a: np.asarray(a), tree)


class HostPageStore:
    """Handle-addressed store of per-layer page blobs in host RAM.

    ``put`` takes a tree of DEVICE arrays (an engine gather's output),
    starts the D2H copy asynchronously and returns a handle immediately;
    the blob is finalized to NumPy on ``drain()`` (once per decode tick)
    or on first ``get`` — whichever comes first — so a demote never
    blocks the decode loop. Blob dtypes are whatever the pool stores
    (int8 pools round-trip bitwise)."""

    def __init__(self, *, tracer: Optional[Tracer] = None):
        self.trace = tracer if tracer is not None else NULL_TRACER
        self._next = 0
        self._blobs: Dict[int, Any] = {}       # handle -> numpy tree
        self._pending: Dict[int, Any] = {}     # handle -> device tree
        self.put_events = 0
        self.bytes_stored = 0                  # current resident bytes
        self.peak_bytes = 0

    def put(self, device_tree) -> int:
        handle = self._next
        self._next += 1
        for leaf in jax.tree.leaves(device_tree):
            start = getattr(leaf, "copy_to_host_async", None)
            if start is not None:
                start()
        self._pending[handle] = device_tree
        self.put_events += 1
        self.bytes_stored += _tree_nbytes(device_tree)
        self.peak_bytes = max(self.peak_bytes, self.bytes_stored)
        return handle

    def drain(self) -> int:
        """Finalize every pending D2H copy; returns how many were."""
        n = len(self._pending)
        if not n:
            return 0
        with self.trace.span("d2h_finalize", tid="tier",
                             args={"blobs": n} if self.trace else None):
            for handle, tree in self._pending.items():
                self._blobs[handle] = _finalize(tree)
            self._pending.clear()
        return n

    def get(self, handle: int):
        """The blob, finalized on demand (covers same-tick demote->use)."""
        if handle in self._pending:
            self._blobs[handle] = _finalize(self._pending.pop(handle))
        return self._blobs[handle]

    def pop(self, handle: int) -> None:
        tree = self._pending.pop(handle, None)
        if tree is None:
            tree = self._blobs.pop(handle)
        self.bytes_stored -= _tree_nbytes(tree)

    def __contains__(self, handle: int) -> bool:
        return handle in self._blobs or handle in self._pending

    def __len__(self) -> int:
        return len(self._blobs) + len(self._pending)


class CopyStream:
    """H2D prefetch stream over a HostPageStore, keyed by handle.

    ``prefetch(handle)`` starts an async ``jax.device_put`` of the blob;
    ``take(handle)`` returns the device tree — the in-flight copy when
    one was started ahead (a prefetch hit), else a demand fetch counted
    as a stall (the decode sweep had to start its own copy)."""

    def __init__(self, store: HostPageStore, *,
                 tracer: Optional[Tracer] = None):
        self.store = store
        self.trace = tracer if tracer is not None else NULL_TRACER
        self._inflight: Dict[int, Any] = {}
        self.prefetch_starts = 0
        self.prefetch_hits = 0
        self.demand_fetches = 0

    def prefetch(self, handle: int) -> None:
        if handle in self._inflight or handle not in self.store:
            return
        self._inflight[handle] = jax.device_put(self.store.get(handle))
        self.prefetch_starts += 1
        self.trace.instant("h2d_prefetch", tid="tier")

    def take(self, handle: int):
        dev = self._inflight.pop(handle, None)
        if dev is not None:
            self.prefetch_hits += 1
            self.trace.instant("h2d_hit", tid="tier")
            return dev
        # copy-stream stall: the consumer arrived before any prefetch —
        # this span IS the paper's prefetch-vs-stall accounting on the
        # timeline (tier.copy_stall_ticks is the counter view of it)
        self.demand_fetches += 1
        with self.trace.span("h2d_demand_fetch", tid="tier"):
            return jax.device_put(self.store.get(handle))

    def cancel(self, handle: int) -> None:
        self._inflight.pop(handle, None)


@dataclasses.dataclass
class SwapRecord:
    """Everything needed to resume a preempted request WITHOUT re-prefill:
    its decode position, the store handles of its full-attention pages,
    live window pages (+ base offset), and recurrent state slots."""
    rid: int
    pos: int
    full: Optional[int] = None       # store handle of full-attn page blob
    full_pages: int = 0              # real (unpadded) page count
    win: Optional[int] = None        # store handle of window page blob
    win_pages: int = 0
    win_base: int = 0                # logical blocks below the blob
    state: Optional[int] = None      # store handle of state-slot export

    def handles(self) -> List[int]:
        return [h for h in (self.full, self.win, self.state)
                if h is not None]


class HostTier:
    """The engine-facing facade: one page store + one copy stream + the
    swap-record registry + the slid-out window archive + telemetry.

    ``max_bytes`` caps the store (None = unbounded): a demotion that
    would exceed the cap is refused (``can_accept``) and the caller falls
    back to the destructive path (evict / plain preempt), loudly counted.
    ``persist_dir`` additionally checkpoints every swap record through
    ``checkpoint.ckpt.AsyncCheckpointer`` (crash-durable swap state; the
    checkpointer re-raises a failed background save on the next swap, so
    persistence failures are never silent)."""

    WIN_ARCHIVE_PAGES = 64           # default cap on archived slid-out pages

    def __init__(self, *, max_bytes: Optional[int] = None,
                 persist_dir: Optional[str] = None,
                 win_archive_pages: Optional[int] = None,
                 tracer: Optional[Tracer] = None):
        self.trace = tracer if tracer is not None else NULL_TRACER
        self.store = HostPageStore(tracer=self.trace)
        self.stream = CopyStream(self.store, tracer=self.trace)
        self.max_bytes = max_bytes
        self._swaps: Dict[int, SwapRecord] = {}
        # rid -> [(base_block, n_pages, handle)]: slid-out window pages,
        # archived for hybrid prefix caching (ROADMAP open 5) — nothing
        # consumes them yet; the cap keeps the archive honest meanwhile
        self._win_archive: Dict[int, List[Tuple[int, int, int]]] = {}
        self._win_archive_order: List[Tuple[int, int]] = []  # (rid, idx)
        self.win_archive_pages_cap = (self.WIN_ARCHIVE_PAGES
                                      if win_archive_pages is None
                                      else win_archive_pages)
        self.win_archived_pages = 0      # currently archived
        self.win_archive_drops = 0       # cap evictions
        # demotion/promotion telemetry (engine exports via tier_stats)
        self.demoted_pages = 0
        self.promoted_pages = 0
        self.cache_demotions = 0         # prefix-cache nodes demoted
        self.cache_promotions = 0        # prefix-cache nodes promoted back
        self.swap_outs = 0
        self.swap_ins = 0
        self.refused_demotions = 0       # cap refusals (fell back, loudly)
        self.reprefill_tokens_saved = 0  # tokens resumed without re-prefill
        self._ckpt = None
        self.persist_dir = persist_dir
        if persist_dir is not None:
            from repro.checkpoint.ckpt import AsyncCheckpointer
            self._ckpt = AsyncCheckpointer()

    # -- capacity ---------------------------------------------------------
    def can_accept(self, nbytes: int) -> bool:
        if self.max_bytes is None:
            return True
        if self.store.bytes_stored + nbytes <= self.max_bytes:
            return True
        self.refused_demotions += 1
        return False

    # -- swap records (request-granular) ----------------------------------
    def record_swap(self, rec: SwapRecord) -> None:
        assert rec.rid not in self._swaps
        self._swaps[rec.rid] = rec
        self.swap_outs += 1
        if self._ckpt is not None:
            import os
            blobs = {str(h): self.store.get(h) for h in rec.handles()}
            self._ckpt.save(
                os.path.join(self.persist_dir, f"swap_{rec.rid}"), blobs,
                extra={"rid": rec.rid, "pos": rec.pos,
                       "full_pages": rec.full_pages,
                       "win_pages": rec.win_pages,
                       "win_base": rec.win_base})

    def has_swap(self, rid: int) -> bool:
        return rid in self._swaps

    def peek_swap(self, rid: int) -> SwapRecord:
        return self._swaps[rid]

    def pop_swap(self, rid: int) -> SwapRecord:
        rec = self._swaps.pop(rid)
        for h in rec.handles():
            self.stream.cancel(h)
            self.store.pop(h)
        self.swap_ins += 1
        self.reprefill_tokens_saved += rec.pos
        return rec

    def drop_swap(self, rid: int) -> None:
        """Discard a swap record without resuming (request abandoned)."""
        rec = self._swaps.pop(rid)
        for h in rec.handles():
            self.stream.cancel(h)
            self.store.pop(h)

    # -- window archive (slid-out pages; consumer: hybrid prefix caching) --
    def archive_window(self, rid: int, base_block: int, n_pages: int,
                       handle: int) -> None:
        self._win_archive.setdefault(rid, []).append(
            (base_block, n_pages, handle))
        self._win_archive_order.append((rid, handle))
        self.win_archived_pages += n_pages
        while self.win_archived_pages > self.win_archive_pages_cap \
                and self._win_archive_order:
            old_rid, old_h = self._win_archive_order.pop(0)
            entries = self._win_archive.get(old_rid, [])
            for i, (_, n, h) in enumerate(entries):
                if h == old_h:
                    entries.pop(i)
                    self.store.pop(h)
                    self.win_archived_pages -= n
                    self.win_archive_drops += 1
                    break

    # -- per-tick maintenance ---------------------------------------------
    def drain(self) -> int:
        """Finalize pending D2H copies; the engine calls this once per
        decode tick (the copy-stream contract's visibility point)."""
        if self._ckpt is not None and self._ckpt.last_error is not None:
            self._ckpt.wait()            # re-raise the failed persist
        return self.store.drain()

    def reset_counters(self) -> None:
        """Zero the telemetry (benchmarks call this after a warmup run so
        the timed replay reports its own rates); store contents, swap
        records and the window archive survive."""
        self.demoted_pages = self.promoted_pages = 0
        self.cache_demotions = self.cache_promotions = 0
        self.swap_outs = self.swap_ins = 0
        self.refused_demotions = 0
        self.reprefill_tokens_saved = 0
        self.win_archive_drops = 0
        self.stream.prefetch_starts = 0
        self.stream.prefetch_hits = 0
        self.stream.demand_fetches = 0
        self.store.put_events = 0
        self.store.peak_bytes = self.store.bytes_stored

    # -- telemetry ---------------------------------------------------------

    #: Every key ``stats()`` returns, in order — the engine's
    #: ``tier_stats`` zero-fills these when the tier is off so metric /
    #: CSV key sets never depend on configuration.
    STAT_KEYS = (
        "demoted_pages", "promoted_pages", "cache_demotions",
        "cache_promotions", "swap_outs", "swap_ins", "refused_demotions",
        "reprefill_tokens_saved", "prefetch_starts", "prefetch_hits",
        "copy_stall_ticks", "prefetch_hit_rate", "host_bytes",
        "host_bytes_peak", "win_archived_pages", "win_archive_drops")

    @staticmethod
    def zero_stats() -> Dict[str, float]:
        return {k: 0.0 for k in HostTier.STAT_KEYS}

    def stats(self) -> Dict[str, float]:
        return {
            "demoted_pages": float(self.demoted_pages),
            "promoted_pages": float(self.promoted_pages),
            "cache_demotions": float(self.cache_demotions),
            "cache_promotions": float(self.cache_promotions),
            "swap_outs": float(self.swap_outs),
            "swap_ins": float(self.swap_ins),
            "refused_demotions": float(self.refused_demotions),
            "reprefill_tokens_saved": float(self.reprefill_tokens_saved),
            "prefetch_starts": float(self.stream.prefetch_starts),
            "prefetch_hits": float(self.stream.prefetch_hits),
            "copy_stall_ticks": float(self.stream.demand_fetches),
            "prefetch_hit_rate": (
                self.stream.prefetch_hits
                / (self.stream.prefetch_hits + self.stream.demand_fetches)
                if (self.stream.prefetch_hits
                    + self.stream.demand_fetches) else 0.0),
            "host_bytes": float(self.store.bytes_stored),
            "host_bytes_peak": float(self.store.peak_bytes),
            "win_archived_pages": float(self.win_archived_pages),
            "win_archive_drops": float(self.win_archive_drops),
        }
