"""Request scheduler: admission control + prefill/decode interleaving.

The loop is the serving-level analogue of Voltra's shared-memory arbiter:
each iteration admits as many pending requests as slots AND pages allow
(prefill), tops up pages the next decode step will write into (allocate-
on-demand, preempting the youngest request on exhaustion — preempted
requests re-enter the queue and resume by re-prefilling prompt +
generated-so-far), then advances every live request one token (decode).

Works with both engines: the dense engine's ``ensure_decode_capacity`` is
a no-op (its lanes are statically reserved — the anti-pattern the paged
engine removes). Preemption-resume is engine-agnostic by construction:
a preempted request re-enters the queue and resumes by re-prefilling
prompt + generated-so-far, which also rebuilds what cannot be swapped
out page-by-page — a hybrid stack's recurrent state slots and its
sliding-window pages (the re-prefill re-admits with the pre-window
blocks already recycled, so resume cost stays O(window) pages too).
With the paged engine's host tier on (``host_tier=True``) the re-prefill
is replaced by a swap-in — pages AND recurrent state promote back from
host RAM — but the scheduler's contract is unchanged: re-queue the
evictee, resubmit later; ``tick`` additionally passes the queue snapshot
to the engine's prefetch streamer so those H2D copies start a tick early.
"""
from __future__ import annotations

import warnings
from collections import deque
from typing import Deque, Optional

from repro.runtime.serving import Request
from repro.runtime.trace import NULL_TRACER


class SchedulerExhausted(RuntimeError):
    """drain() ran out of its step budget with work still in flight — the
    engine is wedged or the budget was too small; outputs are truncated."""


class Scheduler:
    def __init__(self, engine, *, max_admits_per_step: Optional[int] = None):
        self.engine = engine
        self.pending: Deque[Request] = deque()
        self.max_admits_per_step = max_admits_per_step
        self.steps = 0
        self.admitted = 0
        self.preempted = 0
        self.exhausted = False          # drain hit its budget with work left

    def add(self, req: Request) -> None:
        # a malformed decode policy (negative temperature, top_p = 0, ...)
        # fails HERE, at enqueue, where the caller can still see which
        # request it was — not mid-tick inside the admit loop with other
        # requests already in flight
        params = getattr(req, "params", None)
        if params is not None:
            params.validate()
        # stamp arrival at ENQUEUE so TTFT includes queue wait, not just
        # the admission-to-first-token gap (getattr-guarded: tests drive
        # the scheduler with stub engines that have no metrics mixin)
        note = getattr(self.engine, "note_arrival", None)
        if note is not None:
            note(req.rid)
        self.pending.append(req)

    def _admit(self) -> None:
        budget = self.max_admits_per_step
        while self.pending and (budget is None or budget > 0):
            req = self.pending[0]
            gen_before = len(req.generated)
            if not self.engine.submit(req):
                break                       # out of slots or pages
            self.pending.popleft()
            self.admitted += 1
            # charge the admission budget only when a prefill actually
            # ran (the prompt's first sampled token landed in generated).
            # A degenerate request dropped-as-done — over-long prompt,
            # exhausted generation budget — never touched the device, and
            # a stream of them must not starve real admissions this tick.
            if budget is not None and len(req.generated) > gen_before:
                budget -= 1

    def tick(self) -> None:
        """One scheduling round: admit -> prefetch -> decode (the engine's
        step tops up pages itself and reports who it had to preempt). The
        prefetch hook hands the engine's host-tier streamer the queue
        snapshot so swap-ins and radix promotions for NEXT tick's
        admissions start their H2D copies under THIS tick's decode."""
        tr = getattr(self.engine, "trace", NULL_TRACER)
        with tr.span("tick", tid="sched",
                     args={"pending": len(self.pending)} if tr else None):
            with tr.span("admit_loop", tid="sched"):
                self._admit()
            prefetch = getattr(self.engine, "prefetch_pending", None)
            if prefetch is not None:
                with tr.span("prefetch", tid="sched"):
                    prefetch(list(self.pending))
            evicted = self.engine.step() or []
            if evicted:
                self.preempted += len(evicted)
                # resume order: oldest evictee first, ahead of fresh
                # arrivals. evicted[] is youngest-first, so pushing it
                # front-to-back leaves the oldest evictee at the head of
                # the queue.
                for r in evicted:
                    self.pending.appendleft(r)
        self.steps += 1

    def drain(self, max_steps: int = 10_000, *,
              on_exhaust: str = "raise") -> None:
        """Tick until every request finishes or ``max_steps`` is spent.

        Exhausting the budget with requests still pending/live used to
        return silently — a wedged engine then looked like a short trace
        with truncated outputs. Now it fails loudly: ``on_exhaust="raise"``
        (default) raises SchedulerExhausted; ``"warn"`` emits a warning and
        sets ``self.exhausted`` so telemetry consumers (benches) surface it."""
        assert on_exhaust in ("raise", "warn")
        while (self.pending or self.engine.has_live()) \
                and self.steps < max_steps:
            self.tick()
        if self.pending or self.engine.has_live():
            self.exhausted = True
            live = sum(1 for r in getattr(self.engine, "live", [])
                       if r is not None)
            msg = (f"drain() exhausted its {max_steps}-step budget with "
                   f"{len(self.pending)} pending and {live} live requests "
                   f"— outputs are truncated")
            if on_exhaust == "raise":
                raise SchedulerExhausted(msg)
            warnings.warn(msg, stacklevel=2)
