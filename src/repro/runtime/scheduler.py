"""Request scheduler: admission control + prefill/decode interleaving.

The loop is the serving-level analogue of Voltra's shared-memory arbiter:
each iteration admits as many pending requests as slots AND pages allow
(prefill), tops up pages the next decode step will write into (allocate-
on-demand, preempting the youngest request on exhaustion — preempted
requests re-enter the queue and resume by re-prefilling prompt +
generated-so-far), then advances every live request one token (decode).

Works with both engines: the dense engine's ``ensure_decode_capacity`` is
a no-op (its lanes are statically reserved — the anti-pattern the paged
engine removes).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Optional

from repro.runtime.serving import Request


class Scheduler:
    def __init__(self, engine, *, max_admits_per_step: Optional[int] = None):
        self.engine = engine
        self.pending: Deque[Request] = deque()
        self.max_admits_per_step = max_admits_per_step
        self.steps = 0
        self.admitted = 0
        self.preempted = 0

    def add(self, req: Request) -> None:
        self.pending.append(req)

    def _admit(self) -> None:
        budget = self.max_admits_per_step
        while self.pending and (budget is None or budget > 0):
            if not self.engine.submit(self.pending[0]):
                break                       # out of slots or pages
            req = self.pending.popleft()
            self.admitted += 1
            if budget is not None:
                budget -= 1
            if req.done:                    # finished at prefill (eos/budget)
                continue

    def tick(self) -> None:
        """One scheduling round: admit -> decode (the engine's step tops up
        pages itself and reports who it had to preempt)."""
        self._admit()
        evicted = self.engine.step() or []
        if evicted:
            self.preempted += len(evicted)
            # resume order: oldest evictee first, ahead of fresh arrivals.
            # evicted[] is youngest-first, so pushing it front-to-back
            # leaves the oldest evictee at the head of the queue.
            for r in evicted:
                self.pending.appendleft(r)
        self.steps += 1

    def drain(self, max_steps: int = 10_000) -> None:
        while (self.pending or self.engine.has_live()) \
                and self.steps < max_steps:
            self.tick()
