"""Serving engines: paged KV-cache continuous batching (default) and the
legacy dense-slot engine (baseline / fallback for recurrent stacks).

``PagedServingEngine`` is the software analogue of Voltra's shared-memory
architecture (PAPER.md):

* **Dynamic allocation** — full-attention KV lives in a shared page pool
  (``models/api.paged_cache_init``) addressed through per-request block
  tables (``runtime/kv_cache.PageAllocator``). Pages are allocated on
  demand as decode crosses page boundaries and reclaimed on finish, so
  allocated capacity tracks *live tokens*, not ``slots * max_len``.
* **Mixed-grained prefetch** — prompts are right-padded to power-of-two
  length buckets, so ``jax.jit`` traces the prefill once per bucket
  instead of once per distinct prompt length (the dense engine's
  pathology on mixed-length traffic).
* **Shared-memory access efficiency** — ``step()`` keeps position / EOS /
  budget bookkeeping on device and does ONE host sync per step (a single
  ``device_get`` of (tokens, done)), where the dense engine pays one sync
  per live slot per step.
* **Prefix sharing** (``prefix_cache=True``) — concurrent requests with a
  common prompt prefix (system prompts, few-shot templates, multi-turn
  history) alias the SAME physical pages: admission takes the longest
  cached prefix from a radix tree (``runtime/prefix_cache.py``), prefill
  runs on the suffix only, and pages are refcounted with copy-on-write on
  mid-page divergence and LRU eviction of idle cached pages under pool
  pressure. Both decode attention impls work unchanged — block tables
  already indirect through physical pages.
* **Tensor parallelism** (``mesh=``, ISSUE 6) — pass a ``("data",
  "model")`` mesh (``launch/mesh.make_host_mesh``) and the engine shards
  its KV pools and attn/mlp weights over KV heads on the ``model`` axis
  (``parallel/tp.py``): each shard owns its GQA groups' slice of every
  page, block tables / lengths / bookkeeping stay replicated, and each
  traced program wraps exactly its model call + pool scatter in ONE
  ``shard_map`` boundary — sampling and bookkeeping stay outside the
  manual region, so the one-host-sync-per-step contract and every
  feature above (prefix cache, speculative decode, hybrid stacks)
  compose with sharding unchanged. Data parallelism layers on top as
  whole-engine replicas (``runtime/router.py``).

* **Host-tier KV pages with prefetch streamers** (``host_tier=True``,
  ISSUE 7) — a second memory level under the device pool
  (``runtime/host_tier.py``): cold pages DEMOTE to a NumPy-backed host
  store instead of being destroyed, and a copy stream prefetches them
  back one scheduler tick ahead. Three demotion sources replace today's
  destructive paths: idle prefix-cache pages demote before LRU-evicting
  (a radix hit on a host-resident node promotes instead of
  re-prefilling), preempted requests swap out their whole table AND
  their recurrent state slots (resume = promote + scatter + state
  import — NO re-prefill), and slid-out window pages are archived. The
  streamer is mixed-grained like the paper's: page-granular readahead
  for radix promotions, request-granular bulk restore for swap-ins.
  Net: a working set ≫ the device pool serves with zero output change
  (``serve_bench --scenario oversubscribe``). Single-shard only for
  now (``mesh=`` and ``host_tier=`` are mutually exclusive).

* **Hybrid / windowed / recurrent stacks** are first-class since ISSUE 5:
  sliding-window layers (``local_attn``) get *paged ring buffers with
  page recycling* — a second block table whose pages are freed the moment
  they slide entirely out of the attention window
  (``PageAllocator.release_prefix``), bounding live KV at O(window) pages
  per request instead of O(max_len); the flash-decode kernel masks and
  skips below-window pages (``kernels/paged_attention.py`` ``window``).
  Recurrent layers (``ssm`` / ``rglru``) get *fixed-size state slots*
  beside the page pool — written by (bucket-padded, state-masked)
  prefill at admission, rebuilt by re-prefill on preemption-resume, and
  rolled back on speculative rejection by gathering the verify step's
  per-row state checkpoints (``_select_fn``). Continuous batching,
  bucketed prefill, preemption and ``spec_k`` therefore all work on
  griffin-style hybrids.

``DenseServingEngine`` is the seed engine, kept verbatim as the measured
baseline (benchmarks/serve_bench.py): dense max_len lanes, window-sized
ring buffers, per-length prefill retraces. ``ServingEngine(cfg, ...)``
picks the paged engine for every servable block pattern and falls back to
dense — loudly — only for encoder-decoder stacks.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import api
from repro.models import transformer as tfm
from repro.parallel.sharding import NO_RULES, Rules
from repro.parallel.tp import tp_plan
from repro.runtime.drafter import ngram_propose
from repro.runtime.host_tier import HostTier, SwapRecord, _tree_nbytes
from repro.runtime.kv_cache import SCRATCH_PAGE, PageAllocator, PoolStats
from repro.runtime.prefix_cache import PrefixCache, PrefixMatch
from repro.runtime.sampling import (ACCEPT_DRAW, NEG_FILTER, SAMPLE_DRAW,
                                    SamplingParams, draw_keys, fold_keys,
                                    policy_operands, request_params,
                                    sample_rows, scale_mask)
from repro.runtime.trace import Tracer, default_tracer, percentile


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0
    # per-request decode policy (runtime/sampling.py); None = the
    # engine's default. Carried from submit() into the traced step as
    # batched operands — greedy and sampled requests share one trace.
    params: Optional[SamplingParams] = None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _pageable(cfg) -> bool:
    """Whether the paged engine can host this stack: full attention,
    sliding-window attention (paged ring buffers with page recycling) and
    recurrent state (fixed-size slots) are all servable; only
    encoder-decoder stacks fall back to the dense engine."""
    return set(tfm.pattern_for(cfg)) <= set(api.PAGED_SERVABLE_KINDS)


def _win_rid(rid: int):
    """Allocator key of a request's sliding-window block table (kept
    separate from its full-attention table: the two recycle and roll
    back independently)."""
    return ("win", rid)


def _spec_uses(spec: P, axis: str) -> bool:
    """Whether a PartitionSpec shards any dim over mesh axis ``axis``."""
    return any(e == axis or (isinstance(e, tuple) and axis in e)
               for e in spec)


def _run_to_completion(engine, requests: List[Request],
                       max_steps: int) -> List[Request]:
    """Shared drive loop for both engines, routed through the Scheduler so
    an exhausted step budget fails loudly (SchedulerExhausted) instead of
    silently returning truncated outputs."""
    from repro.runtime.scheduler import Scheduler
    sched = Scheduler(engine)
    for r in requests:
        sched.add(r)
    sched.drain(max_steps=max_steps)
    return [r for r in requests if r.done]


class ServingMetricsMixin:
    """Shared observability layer for both engines (ISSUE 8): request
    lifecycle bookkeeping (arrival / first token / last token), the timed
    ``submit``/``step`` wrappers that feed the tracer and the wall-clock
    accumulators, and the unified ``metrics()`` snapshot.

    The engine class provides ``_submit`` / ``_step`` (the untimed
    implementations) plus the five ``*_stats()`` methods; the mixin owns
    everything that used to be duplicated between ``DenseServingEngine``
    and ``PagedServingEngine`` — ``decode_steps`` / ``decoded_tokens`` /
    ``step_wall_s`` / ``first_token_at`` — and adds:

    * ``tick_wall_s`` — wall time of whole decode ticks (only ticks with
      live slots count, so an idle scheduler doesn't dilute the ratio);
    * ``prefill_wall_s`` — wall time of successful admissions;
    * **temporal utilization** = ``step_wall_s / tick_wall_s``: the
      fraction of each decode tick spent in the device program (dispatch
      + the one host sync) rather than host-side bookkeeping, draft,
      rollback or tier traffic — the serving-level analogue of the
      paper's temporal-utilization metric (compute cycles over total
      cycles; Fig. 6's 2.12-2.94x win is this ratio moved by prefetch).

    TTFT is arrival -> first emitted token, where *arrival* is the
    earliest of ``Scheduler.add`` (queue wait included) and the first
    ``submit`` (direct-submit callers). TPOT is (last - first) /
    (tokens - 1) per request with >= 2 tokens. Percentiles are computed
    on demand in ``metrics()``; per-request stamps live in plain dicts.
    """

    def _init_metrics(self, tracer: Optional[Tracer]) -> None:
        """Engine-constructor hook: install the tracer (falling back to
        the process default — ``trace.set_default_tracer`` — so bench
        harnesses can turn on tracing for every engine they build) and
        zero every counter the mixin owns."""
        self.trace = tracer if tracer is not None else default_tracer()
        self.decode_steps = 0
        self.decoded_tokens = 0
        self.step_wall_s = 0.0        # device dispatch + sync, decode only
        self.tick_wall_s = 0.0        # whole decode ticks (live slots only)
        self.prefill_wall_s = 0.0     # successful admissions (prefill wall)
        self.first_token_at: Dict[int, float] = {}
        self._arrival_at: Dict[int, float] = {}
        self._last_token_at: Dict[int, float] = {}
        self._tokens_emitted: Dict[int, int] = {}

    # -- request lifecycle -------------------------------------------------

    def note_arrival(self, rid: int) -> None:
        """Stamp a request's arrival (idempotent — the earliest stamp
        wins). ``Scheduler.add`` calls this on enqueue so TTFT includes
        queue wait; ``submit`` calls it too as the fallback for callers
        that drive the engine directly."""
        if rid not in self._arrival_at:
            self._arrival_at[rid] = time.perf_counter()
            self.trace.begin_async("request", rid)

    def _note_emitted(self, rid: int, n: int = 1) -> None:
        now = time.perf_counter()
        if rid not in self.first_token_at:
            self._arrival_at.setdefault(rid, now)
            self.first_token_at[rid] = now
            if self.trace:
                self.trace.instant("first_token", args={"rid": rid})
        self._last_token_at[rid] = now
        self._tokens_emitted[rid] = self._tokens_emitted.get(rid, 0) + n

    def _note_finished(self, rid: int) -> None:
        self.trace.end_async("request", rid)

    # -- timed wrappers ----------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Admit ``req`` (see the engine's ``_submit`` for semantics),
        timed and traced."""
        self.note_arrival(req.rid)
        tr = self.trace
        t0 = time.perf_counter()
        with tr.span("admit", args={"rid": req.rid} if tr else None):
            ok = self._submit(req)
        if ok:
            self.prefill_wall_s += time.perf_counter() - t0
        return ok

    def step(self) -> List[Request]:
        """Advance every live slot (see the engine's ``_step``), timed and
        traced. Idle ticks (no live slots — e.g. everything still queued)
        run untimed so ``tick_wall_s`` divides only real decode work."""
        if not self.has_live():
            return self._step()
        t0 = time.perf_counter()
        with self.trace.span("decode_tick"):
            out = self._step()
        self.tick_wall_s += time.perf_counter() - t0
        return out

    # -- the unified snapshot ----------------------------------------------

    def _latency_samples(self):
        ttfts = [t - self._arrival_at[rid]
                 for rid, t in self.first_token_at.items()
                 if rid in self._arrival_at]
        tpots = []
        for rid, n in self._tokens_emitted.items():
            if n > 1 and rid in self.first_token_at:
                tpots.append((self._last_token_at[rid]
                              - self.first_token_at[rid]) / (n - 1))
        return ttfts, tpots

    def metrics(self) -> Dict[str, object]:
        """One flat snapshot of everything, under stable namespaced keys:
        ``engine.*`` (throughput counters), ``latency.*`` (TTFT / TPOT
        percentiles, seconds), ``util.*`` (wall-clock split + temporal
        utilization), and every subsystem's stats under ``pool.*`` /
        ``spec.*`` / ``prefix.*`` / ``tier.*`` / ``shard.*``. The key set
        is IDENTICAL across engines and configurations — subsystems that
        are off report zeros, never missing keys — so CSV columns and
        dashboards line up between runs (tests/test_metrics.py)."""
        ttfts, tpots = self._latency_samples()
        tick = self.tick_wall_s
        m: Dict[str, object] = {
            "engine.kind": type(self).__name__,
            "engine.decode_steps": float(self.decode_steps),
            "engine.decoded_tokens": float(self.decoded_tokens),
            "engine.prefill_traces": float(self.prefill_traces),
            "latency.requests": float(len(ttfts)),
            "latency.ttft_p50_s": percentile(ttfts, 0.50),
            "latency.ttft_p95_s": percentile(ttfts, 0.95),
            "latency.ttft_mean_s": (sum(ttfts) / len(ttfts)
                                    if ttfts else 0.0),
            "latency.tpot_p50_s": percentile(tpots, 0.50),
            "latency.tpot_p95_s": percentile(tpots, 0.95),
            "latency.tpot_mean_s": (sum(tpots) / len(tpots)
                                    if tpots else 0.0),
            "util.step_wall_s": self.step_wall_s,
            "util.tick_wall_s": tick,
            "util.prefill_wall_s": self.prefill_wall_s,
            "util.temporal": self.step_wall_s / tick if tick > 0 else 0.0,
        }
        for ns, stats in (
                ("pool", dataclasses.asdict(self.pool_stats())),
                ("spec", self.spec_stats()),
                ("prefix", self.prefix_stats()),
                ("tier", self.tier_stats()),
                ("shard", self.shard_stats()),
                ("sampling", self.sampling_stats())):
            for k, v in stats.items():
                m[f"{ns}.{k}"] = float(v) if isinstance(v, int) else v
        return m

    def reset_metrics(self) -> None:
        """The single warm-up reset point (benchmarks call this between
        the cache-warming pass and the timed replay): zero every latency
        and wall-clock counter the mixin owns, then the engine's own
        subsystem counters (``_reset_subsystem_counters``). Trace events
        are NOT discarded — a ``reset_metrics`` instant marks the
        boundary instead, so a trace of warm-up + replay stays one
        coherent timeline. jit trace caches (``prefill_traces`` /
        seen-bucket sets) survive too: retrace identity is a lifetime
        fact, not a per-phase rate."""
        self.decode_steps = 0
        self.decoded_tokens = 0
        self.step_wall_s = 0.0
        self.tick_wall_s = 0.0
        self.prefill_wall_s = 0.0
        self.first_token_at.clear()
        self._arrival_at.clear()
        self._last_token_at.clear()
        self._tokens_emitted.clear()
        self.trace.instant("reset_metrics")
        self._reset_subsystem_counters()

    def _count_tokens(self, pol: Optional[SamplingParams], n: int) -> None:
        """Attribute ``n`` emitted tokens to the greedy or sampled bucket
        of ``sampling_stats`` (``pol`` is the emitting slot's policy)."""
        if pol is None or pol.is_greedy:
            self.greedy_tokens += n
        else:
            self.sampled_tokens += n

    def _reset_subsystem_counters(self) -> None:
        pass                          # engines with extra counters override


def ServingEngine(cfg, params, **kwargs):
    """Engine factory: paged engine for every servable block pattern —
    full attention, sliding-window (local_attn) and recurrent (ssm/rglru)
    layers included — dense-slot engine only for encoder-decoder stacks.

    A dense fallback cannot honor the paged feature kwargs. Dropping them
    silently (the pre-ISSUE-5 behavior) meant a caller who asked for
    speculative decode or prefix sharing got neither and no signal; now
    every dropped kwarg whose value differs from the paged engine's
    default — i.e. the caller actually asked for something — is named in
    a warning, and a truthy ``spec_k``, which changes the output contract
    (verify-step semantics, ``spec_stats``), raises instead. Kwargs still
    at their defaults drop quietly: launchers pass the full knob set
    unconditionally, and warning on never-requested features would turn
    the loud-fallback signal into noise."""
    if _pageable(cfg):
        return PagedServingEngine(cfg, params, **kwargs)
    paged_defaults = {"page_size": 16, "num_pages": None,
                      "attn_impl": "kernel", "prefix_cache": False,
                      "spec_k": 0, "spec_ngram": 3, "drafter": None,
                      "mesh": None, "host_tier": False}
    dropped = []
    for k, default in paged_defaults.items():
        if k in kwargs:
            v = kwargs.pop(k)
            if k == "spec_k" and v:
                raise ValueError(
                    f"spec_k={v} requested, but {cfg.name!r} "
                    f"(pattern {tfm.pattern_for(cfg)}) is not servable by "
                    f"the paged engine and the dense fallback has no "
                    f"speculative decode — drop spec_k or serve a paged-"
                    f"servable stack")
            if k == "drafter" and v is not None:
                raise ValueError(
                    f"a drafter was passed, but {cfg.name!r} (pattern "
                    f"{tfm.pattern_for(cfg)}) is not servable by the paged "
                    f"engine and the dense fallback has no speculative "
                    f"verify step to feed it — drop the drafter or serve "
                    f"a paged-servable stack")
            if v != default:
                dropped.append(f"{k}={v!r}")
    if dropped:
        warnings.warn(
            f"{cfg.name!r} (pattern {tfm.pattern_for(cfg)}) falls back to "
            f"DenseServingEngine, which ignores the paged-engine "
            f"kwarg(s) {dropped} — the features they configure will NOT "
            f"be active", stacklevel=2)
    return DenseServingEngine(cfg, params, **kwargs)


# ===========================================================================
# Paged engine
# ===========================================================================


class PagedServingEngine(ServingMetricsMixin):
    """Continuous batching over a paged KV cache with bucketed prefill."""

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 rules: Rules = NO_RULES, eos_id: int = -1,
                 temperature: float = 0.0, seed: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 attn_impl: str = "kernel", prefix_cache: bool = False,
                 spec_k: int = 0, spec_ngram: int = 3, drafter=None,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 host_tier: bool = False,
                 tracer: Optional[Tracer] = None):
        if not _pageable(cfg):
            raise ValueError(
                f"paged serving cannot host pattern "
                f"{tfm.pattern_for(cfg)}; use DenseServingEngine")
        assert page_size >= 1 and page_size & (page_size - 1) == 0, \
            "page_size must be a power of two"
        if attn_impl not in ("kernel", "gather"):
            raise ValueError(f"attn_impl must be kernel|gather: {attn_impl}")
        if drafter is not None and not spec_k:
            raise ValueError(
                "a drafter only runs inside the speculative verify step — "
                "pass spec_k > 0 with it (or drop the drafter)")
        if host_tier and mesh is not None:
            raise ValueError(
                "host_tier=True is single-shard only: swap blobs would "
                "have to gather/scatter each shard's KV-head slice through "
                "the manual boundary — TP + tiering is an open item")
        # block-kind split: full-attention layers share one block table,
        # sliding-window layers a second (recycled) one, recurrent layers
        # hold fixed-size per-slot state beside the pool
        self._kinds = tuple(tfm.pattern_for(cfg))
        _, self._tail = tfm.layer_plan(cfg)
        present = set(self._kinds) | set(self._tail)
        self.has_full = bool(present & set(api.PAGEABLE_KINDS))
        self.has_win = bool(present & set(api.WINDOW_KINDS))
        self.has_state = bool(present & set(api.STATE_KINDS))
        self.window = cfg.hybrid.window if self.has_win else 0
        if prefix_cache and (self.has_win or self.has_state):
            raise ValueError(
                "prefix_cache needs an attention-only stack: recurrent "
                "state cannot be reconstructed from shared KV pages, and "
                "window pages are recycled per-request")
        if self.has_win and self.window < 1:
            raise ValueError("local_attn layers need cfg.hybrid.window >= 1")
        # decode attention impl rides on the (frozen) config so it reaches
        # layers.attention_decode through the jitted step without an extra
        # traced operand; "kernel" = in-kernel block-table gather (Pallas
        # flash-decode), "gather" = PR-1 dense pool gather (bench baseline)
        cfg = dataclasses.replace(cfg, paged_attn_impl=attn_impl)
        self.attn_impl = attn_impl
        self.cfg, self.params = cfg, params
        self.page_size = page_size
        self.max_len = -(-max_len // page_size) * page_size
        self.max_blocks = self.max_len // page_size
        self.slots = slots
        self.rules, self.eos_id = rules, eos_id
        # decode policy: `sampling` is the engine default for requests
        # without their own params; the legacy `temperature` kwarg builds
        # one when `sampling` isn't given. Per-slot policies ride into
        # every traced program as stacked operands (runtime/sampling.py),
        # so a mixed greedy/sampled batch shares one trace.
        self.default_params = (
            sampling if sampling is not None
            else SamplingParams(temperature=temperature)).validate()
        self.temperature = self.default_params.temperature
        self.seed = int(seed) & 0x7FFFFFFF
        self._policy: List[Optional[SamplingParams]] = [None] * slots
        self._rid_host = [0] * slots          # rid per slot (PRNG fold)
        self._samp_idx = [0] * slots          # next generated-token index
        self._init_metrics(tracer)    # tracer + shared latency counters

        # tensor parallelism: one TPPlan per (config, mesh) decides what
        # shards (parallel/tp.py) — KV-head pools and attn/mlp weights over
        # the mesh's "model" axis, everything else replicated. mesh=None is
        # the single-shard engine, byte-for-byte the pre-TP code paths.
        # Inside shard_map bodies the model uses the plan's ManualRules
        # (explicit psum at the two contraction points); the GSPMD `rules`
        # kwarg keeps steering the non-TP path.
        self.tp = tp_plan(cfg, mesh)
        self._model_rules = self.tp.rules if self.tp is not None else rules
        if self.tp is not None:
            self._param_specs = self.tp.param_specs(cfg)
            self.params = self.tp.put(self.params, self._param_specs)

        usable = num_pages if num_pages is not None \
            else slots * self.max_blocks
        self.alloc = PageAllocator(usable, page_size)
        # prefix sharing: radix tree over page-aligned token chunks mapping
        # to refcounted physical pages (runtime/prefix_cache.py). Off by
        # default: sharing keeps refcount-0 pages cached in the pool, which
        # callers that meter allocated_pages must opt into.
        self.prefix: Optional[PrefixCache] = \
            PrefixCache(self.alloc, tracer=self.trace) \
            if prefix_cache else None
        # two-tier memory hierarchy: host-RAM page store + copy stream
        # (runtime/host_tier.py). Off by default — demotion keeps blobs
        # alive in host RAM, which callers that meter memory opt into.
        self.tier: Optional[HostTier] = \
            HostTier(tracer=self.trace) if host_tier else None
        # pool row 0 is the scratch page -> usable + 1 physical rows
        self.cache = api.paged_cache_init(cfg, slots, usable + 1, page_size)
        if self.tp is not None:
            # shard the pools over KV heads at rest: block tables stay
            # replicated (logical pages are a host-side fact), each shard
            # owns its GQA groups' slice of EVERY page
            self._cache_specs = self.tp.cache_specs(cfg, self.cache)
            self.cache = self.tp.put(self.cache, self._cache_specs)
        self.block_table = jnp.zeros((slots, self.max_blocks), jnp.int32)
        # sliding-window block table: logical block j still means absolute
        # positions [j*page, (j+1)*page), but entries that slid below the
        # window are recycled back to SCRATCH (the kernel skips them)
        self.win_table = jnp.zeros((slots, self.max_blocks), jnp.int32)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur_tok = jnp.zeros((slots, 1), jnp.int32)
        self.live_mask = jnp.zeros((slots,), bool)
        self.gen_cnt = jnp.zeros((slots,), jnp.int32)
        self.max_new_arr = jnp.zeros((slots,), jnp.int32)

        self.live: List[Optional[Request]] = [None] * slots
        self._pos_host = [0] * slots          # mirror of self.pos for live
        self._admit_seq = [0] * slots         # admission order (preemption)
        self._admit_counter = 0

        # speculative decode: each step verifies spec_k drafted tokens
        # plus the current one in a single multi-token kernel sweep,
        # accepting a prefix by rejection sampling (exact-greedy matching
        # at temperature 0) + one bonus token. Drafts come from `drafter`
        # (runtime/drafter.py — e.g. DraftModelDrafter) or, when None,
        # the built-in host-side n-gram prompt lookup. spec_k = 0 is the
        # plain one-token-per-step path.
        self.spec_k = spec_k
        self.spec_ngram = spec_ngram
        self.drafter = drafter

        # telemetry (decode_steps / decoded_tokens / wall clocks /
        # first_token_at live in ServingMetricsMixin, shared with the
        # dense engine)
        self.prefill_traces = 0               # == number of length buckets
        self.prompt_tokens = 0                # logical prompt tokens admitted
        self.prefilled_tokens = 0             # tokens actually prefilled
        self.cow_copies = 0                   # device page copies (CoW)
        self.spec_drafted = 0                 # draft tokens proposed
        self.spec_accepted = 0                # draft tokens accepted
        self.spec_slot_steps = 0              # (live slot, verify step) pairs
        self.win_recycled_pages = 0           # window pages slid out + freed
        self.greedy_requests = 0              # requests by effective policy
        self.sampled_requests = 0
        self.greedy_tokens = 0                # emitted tokens by policy
        self.sampled_tokens = 0
        # retrace telemetry: incremented at TRACE time inside the step
        # programs (a python side effect runs once per compilation), so
        # a mixed greedy+sampled batch proves its one-trace contract by
        # these staying at 1 (tests/test_sampling.py)
        self.step_traces = 0
        self.spec_traces = 0

        self._step_fn = jax.jit(self._make_step())
        self._spec_fn = jax.jit(self._make_spec_step()) if spec_k else None
        self._select_fn = jax.jit(self._make_select()) \
            if (spec_k and self.has_state) else None
        self._prefill_fn = jax.jit(self._make_prefill())
        self._prefill_shared_fn = jax.jit(self._make_prefill_shared())
        self._cow_fn = jax.jit(self._make_cow())
        # host-tier page IO: kind-filtered gather/scatter pairs (full-attn
        # pools and window pools move independently — a swap record holds
        # one blob per table) plus recurrent state-slot export/import
        if host_tier:
            full_kinds = set(api.PAGEABLE_KINDS)
            win_kinds = set(api.WINDOW_KINDS)
            self._gather_full = jax.jit(self._make_pool_gather(full_kinds))
            self._scatter_full = jax.jit(self._make_pool_scatter(full_kinds))
            self._gather_win = jax.jit(self._make_pool_gather(win_kinds))
            self._scatter_win = jax.jit(self._make_pool_scatter(win_kinds))
            if self.has_state:
                # the swap-out half of the carried-over PR 5 open: a
                # preempted hybrid request carries its recurrent state to
                # host RAM instead of rebuilding it by re-prefill
                self._state_export_fn = jax.jit(
                    lambda c, s: api.state_slot_export(cfg, c, s))
                self._state_import_fn = jax.jit(
                    lambda c, s, st: api.state_slot_import(cfg, c, s, st))
        self._seen_buckets: set = set()

    # -- jitted device programs -------------------------------------------
    #
    # TP boundary discipline: each traced program keeps its single jax.jit
    # wrapper, and INSIDE it exactly the model call + page-pool
    # scatter/gather is shard_map'd (`_wrap_sharded`). Sampling, the PRNG
    # key split and the slot-bookkeeping updates stay outside the manual
    # region but inside the jit — typed PRNG keys never cross the manual
    # boundary, replicated bookkeeping compiles as trivially-partitioned
    # ops, and the one-dispatch / one-host-sync-per-step contract is
    # untouched by sharding.

    def _wrap_sharded(self, fn, n_rep: int):
        """Wrap a ``(params, cache, *replicated) -> (out, new_cache)``
        model call in the plan's ONE manual boundary; identity when
        single-shard. ``n_rep`` counts the replicated operands after
        (params, cache)."""
        if self.tp is None:
            return fn
        rep = (P(),) * n_rep
        return self.tp.shard(
            fn,
            in_specs=(self._param_specs, self._cache_specs) + rep,
            out_specs=(P(), self._cache_specs))

    def _decode_call(self):
        """The model call both decode-side programs share — the exact
        extent of the TP manual region for a decode step. Works for T=1
        rows (plain step) and T=spec_k+1 blocks (speculative verify): the
        per-shard flash-decode sweep sees its local KV-head slice of the
        pool and the GQA fold is untouched (kernels/paged_attention.py)."""
        cfg, rules, has_win = self.cfg, self._model_rules, self.has_win

        def call(params, cache, block_table, win_table, tok, pos):
            return api.decode_step(
                cfg, params, cache, tok, pos, rules=rules,
                block_table=block_table,
                win_block_table=win_table if has_win else None)

        return call

    def _make_step(self):
        cfg = self.cfg
        eos, max_len = self.eos_id, self.max_len
        decode = self._wrap_sharded(self._decode_call(), 4)

        def step(params, cache, block_table, win_table, cur_tok, pos, live,
                 gen, max_new, pol):
            # trace-time side effect: runs once per compilation, never at
            # execution — the retrace telemetry behind the one-trace-per-
            # policy-mix contract. Policies arrive as (slots,) operands
            # (`pol`), so greedy and sampled rows share this trace.
            # repro-lint: disable=retrace-hazard — counting traces IS the point
            self.step_traces += 1
            logits, cache = decode(params, cache, block_table, win_table,
                                   cur_tok, pos)
            toks = sample_rows(logits[..., : cfg.vocab], pol)
            livei = live.astype(jnp.int32)
            pos2 = pos + livei
            gen2 = gen + livei
            done = live & ((toks == eos) | (gen2 >= max_new)
                           | (pos2 >= max_len - 1))
            live2 = live & ~done
            cur2 = jnp.where(live[:, None], toks[:, None], cur_tok)
            return cache, cur2, pos2, gen2, live2, done, toks

        return step

    def _make_spec_step(self):
        """Speculative verify-step device program: scatter the whole (B, T)
        token block's KV into the pages and score every row in ONE causal
        page sweep (api.decode_step with T = spec_k + 1), returning per
        row a rejection-sampling accept bit for its drafted token and the
        token to emit if the step stops there — the step's only host
        sync. Acceptance is distribution-preserving (runtime/sampling.py:
        both drafters propose deterministically, so q is a point mass and
        ``u < p(draft)`` is the full accept rule; greedy rows reduce to
        exact argmax matching, bit-identical to the pre-ISSUE-9 engine).
        The emitted token for a verify row is a RESIDUAL sample — the
        policy distribution with the rejected draft's mass removed — and
        for the bonus row (nothing left to verify) a full sample; greedy
        rows emit the argmax either way. The prefix walk, rollback and
        finish bookkeeping stay host-side: the accepted length is
        data-dependent per request, exactly what a fixed-shape jitted
        program can't express without padding every outcome. On stacks
        with recurrent layers the returned cache carries CHECKPOINTED
        states — a T axis of per-row states — which ``_select_fn``
        collapses to each slot's accepted row. (The checkpointed leaves
        still match ``_cache_specs``: specs constrain only the dims they
        name, state slots are P() at any rank.)"""
        cfg = self.cfg
        decode = self._wrap_sharded(self._decode_call(), 4)

        def spec(params, cache, block_table, win_table, tok_block, pos,
                 n_draft, pol):
            # repro-lint: disable=retrace-hazard — counting traces IS the point
            self.spec_traces += 1     # trace-time retrace telemetry
            logits, cache = decode(params, cache, block_table, win_table,
                                   tok_block, pos)
            B, T = tok_block.shape
            z = logits[..., : cfg.vocab].astype(jnp.float32)
            V = z.shape[-1]
            z = z.reshape(B * T, V)

            def rep(a):               # (B,) slot operand -> (B*T,) rows
                return jnp.repeat(a, T)

            temp = rep(pol["temp"])
            z = scale_mask(z, temp, rep(pol["top_k"]), rep(pol["top_p"]))
            greedy = jnp.argmax(z, -1).astype(jnp.int32)
            # row t of slot s decides generated-token index idx[s] + t;
            # its key is the same fold the non-speculative step would use
            # for that position, so spec-off/spec-on agree wherever the
            # draw stream lines up (e.g. zero drafts, or temperature 0)
            t_off = jnp.tile(jnp.arange(T, dtype=jnp.int32), B)
            keys = fold_keys(rep(pol["seed"]), rep(pol["rid"]),
                             rep(pol["idx"]) + t_off)
            # the drafted token under test at verify row t is
            # tok_block[:, t + 1]; the last row has no draft (bonus row)
            draft = jnp.concatenate(
                [tok_block[:, 1:], jnp.zeros((B, 1), jnp.int32)],
                axis=1).reshape(-1)
            p_draft = jnp.take_along_axis(
                jax.nn.softmax(z, axis=-1), draft[:, None], axis=-1)[:, 0]
            u = jax.vmap(jax.random.uniform)(draw_keys(keys, ACCEPT_DRAW))
            accept = jnp.where(temp > 0, u < p_draft, greedy == draft)
            # emission token if the step stops at this row: residual
            # sample (draft's mass removed) on a rejected verify row,
            # full sample on the bonus row, argmax on greedy rows. One
            # noise draw serves both candidates — only one is consumed.
            is_verify = t_off < rep(n_draft)
            z_res = jnp.where(
                (jnp.arange(V)[None, :] == draft[:, None])
                & is_verify[:, None], NEG_FILTER, z)
            g = jax.vmap(lambda k: jax.random.gumbel(
                k, (V,), jnp.float32))(draw_keys(keys, SAMPLE_DRAW))
            noise = jnp.where(temp > 0, 1.0, 0.0)[:, None] * g
            full_tok = jnp.argmax(z + noise, -1).astype(jnp.int32)
            res_tok = jnp.argmax(z_res + noise, -1).astype(jnp.int32)
            emit = jnp.where(
                temp > 0, jnp.where(is_verify, res_tok, full_tok), greedy)
            return (cache, accept.reshape(B, T),
                    emit.astype(jnp.int32).reshape(B, T))

        return spec

    def _make_select(self):
        """Recurrent-state rollback for speculative decode: the verify
        step's T-step recurrence checkpointed the state after EVERY block
        row (ssm_decode / rglru_decode with T > 1); given each slot's
        accepted row index this gathers the state the T=1 engine would
        have reached — the state-slot analogue of the page rollback
        ``PageAllocator.truncate_to`` performs for KV."""
        kinds, tail = self._kinds, self._tail
        state = set(api.STATE_KINDS)

        def sel(cache, idx):          # idx: (slots,) accepted row per slot
            def g_tail(leaf):         # (B, T, ...) -> (B, ...)
                ix = idx.reshape((-1,) + (1,) * (leaf.ndim - 1))
                return jnp.take_along_axis(leaf, ix, axis=1)[:, 0]

            def g_scan(leaf):         # (L, B, T, ...) -> (L, B, ...)
                ix = idx.reshape((1, -1) + (1,) * (leaf.ndim - 2))
                return jnp.take_along_axis(
                    leaf, jnp.broadcast_to(
                        ix, (leaf.shape[0],) + ix.shape[1:]), axis=2)[:, :, 0]

            new_scan = {}
            for j, kd in enumerate(kinds):
                e = cache["scan"].get(str(j))
                if e is None:
                    continue
                new_scan[str(j)] = jax.tree.map(g_scan, e) \
                    if kd in state else e
            new_tail = [jax.tree.map(g_tail, e) if kd in state else e
                        for e, kd in zip(cache["tail"], tail)]
            return {"scan": new_scan, "tail": new_tail}

        return sel

    def _make_prefill(self):
        cfg = self.cfg
        rules = self._model_rules
        page = self.page_size
        kinds, tail = self._kinds, self._tail
        hybrid = self.has_win or self.has_state

        def model(params, cache, tokens, length, pages, pages_win, slot):
            # hybrid stacks prefill with paged_kv: recurrent state updates
            # are masked past `length` (bucket padding never leaks into
            # the state slot) and local_attn yields full-sequence kv for
            # the window-page scatter below
            logits, cache1, _ = api.prefill(cfg, params, {"tokens": tokens},
                                            rules=rules, length=length,
                                            paged_kv=hybrid)

            # scatter the prompt's kv blocks into the page pools: full-
            # attention layers through `pages`, sliding-window layers
            # through `pages_win` (whose below-window AND beyond-
            # allocation/bucket-padding blocks are SCRATCH_PAGE); write
            # recurrent layers' state into this request's slot.
            def merge_scan(pool, one, pg):      # (L,P,pg,..) <- (L,1,Sb,..)
                L = pool.shape[0]
                nb = one.shape[2] // page
                blocks = one.reshape((L, nb, page) + one.shape[3:])
                return pool.at[:, pg].set(blocks.astype(pool.dtype))

            def merge_tail(pool, one, pg):      # (P,pg,..) <- (1,Sb,..)
                nb = one.shape[1] // page
                blocks = one.reshape((nb, page) + one.shape[2:])
                return pool.at[pg].set(blocks.astype(pool.dtype))

            def state_scan(st, one):            # (L,slots,..) <- (L,1,..)
                return st.at[:, slot].set(one[:, 0].astype(st.dtype))

            def state_tail(st, one):            # (slots,..) <- (1,..)
                return st.at[slot].set(one[0].astype(st.dtype))

            def merged(kd, e, e1, scan_axis):
                if kd in api.STATE_KINDS:
                    return jax.tree.map(state_scan if scan_axis
                                        else state_tail, e, e1)
                pg = pages if kd in api.PAGEABLE_KINDS else pages_win
                mg = merge_scan if scan_axis else merge_tail
                return jax.tree.map(lambda p_, o, _pg=pg: mg(p_, o, _pg),
                                    e, e1)

            new_cache = {
                "scan": {str(j): merged(kd, cache["scan"][str(j)],
                                        cache1["scan"][str(j)], True)
                         for j, kd in enumerate(kinds)
                         if str(j) in cache["scan"]},
                "tail": [merged(kd, e, e1, False)
                         for kd, e, e1 in zip(tail, cache["tail"],
                                              cache1["tail"])],
            }
            return logits, new_cache

        model = self._wrap_sharded(model, 5)

        def pf(params, cache, block_table, win_table, pos, cur_tok, live,
               gen, max_new_arr, tokens, length, pages, pages_win, row,
               row_win, slot, req_max_new, pol):
            logits, new_cache = model(params, cache, tokens, length, pages,
                                      pages_win, slot)
            tok = sample_rows(logits[..., : cfg.vocab], pol)[0]
            block_table = block_table.at[slot].set(row)
            win_table = win_table.at[slot].set(row_win)
            pos = pos.at[slot].set(length)
            cur_tok = cur_tok.at[slot, 0].set(tok)
            live = live.at[slot].set(True)
            gen = gen.at[slot].set(1)
            max_new_arr = max_new_arr.at[slot].set(req_max_new)
            return (new_cache, block_table, win_table, pos, cur_tok, live,
                    gen, max_new_arr, tok)

        return pf

    def _make_prefill_shared(self):
        """Prefill a request whose first ``prefix_len`` tokens' KV already
        sits in the pool (prefix-cache hit): gather the matched pages into
        a per-layer prefix buffer, run the model over the SUFFIX only
        (api.prefill prefix_kv — the FLOPs saving the prefix cache exists
        for), and scatter the suffix k/v token-by-token into its pages
        (``phys_tok``/``row_tok``: physical page + row per suffix token,
        SCRATCH for bucket padding — token-granular because a CoW'd
        divergence can start mid-page)."""
        cfg = self.cfg
        rules = self._model_rules
        page = self.page_size

        def model(params, cache, tokens, length, prefix_pages, prefix_len,
                  phys_tok, row_tok):
            npb = prefix_pages.shape[0]

            def gather_scan(pool):          # (L,P,pg,..) -> (L,1,npb*pg,..)
                g = jnp.take(pool, prefix_pages, axis=1)
                return g.reshape((pool.shape[0], 1, npb * page)
                                 + pool.shape[3:])

            def gather_tail(pool):          # (P,pg,..) -> (1,npb*pg,..)
                g = jnp.take(pool, prefix_pages, axis=0)
                return g.reshape((1, npb * page) + pool.shape[2:])

            prefix_kv = {
                "scan": jax.tree.map(gather_scan, cache["scan"]),
                "tail": [jax.tree.map(gather_tail, cp)
                         for cp in cache["tail"]],
            }
            logits, cache1, _ = api.prefill(cfg, params, {"tokens": tokens},
                                            rules=rules, length=length,
                                            prefix_kv=prefix_kv,
                                            prefix_len=prefix_len)

            def merge_scan(pool, one):      # (L,P,pg,..) <- (L,1,Sb,..)
                return pool.at[:, phys_tok, row_tok].set(
                    one[:, 0].astype(pool.dtype))

            def merge_tail(pool, one):      # (P,pg,..) <- (1,Sb,..)
                return pool.at[phys_tok, row_tok].set(
                    one[0].astype(pool.dtype))

            new_cache = {
                "scan": jax.tree.map(merge_scan, cache["scan"],
                                     cache1["scan"]),
                "tail": [jax.tree.map(merge_tail, cp, c1)
                         for cp, c1 in zip(cache["tail"], cache1["tail"])],
            }
            return logits, new_cache

        model = self._wrap_sharded(model, 6)

        def pf(params, cache, block_table, pos, cur_tok, live, gen,
               max_new_arr, tokens, length, prefix_pages, prefix_len,
               phys_tok, row_tok, row, slot, req_max_new, pol):
            logits, new_cache = model(params, cache, tokens, length,
                                      prefix_pages, prefix_len, phys_tok,
                                      row_tok)
            tok = sample_rows(logits[..., : cfg.vocab], pol)[0]
            block_table = block_table.at[slot].set(row)
            pos = pos.at[slot].set(prefix_len + length)
            cur_tok = cur_tok.at[slot, 0].set(tok)
            live = live.at[slot].set(True)
            gen = gen.at[slot].set(1)
            max_new_arr = max_new_arr.at[slot].set(req_max_new)
            return (new_cache, block_table, pos, cur_tok, live, gen,
                    max_new_arr, tok)

        return pf

    def _make_cow(self):
        """Device-side copy-on-write: duplicate one physical page (every
        page-pool layer) into a fresh private page, so a request can
        diverge inside a shared page without corrupting the other
        readers. Recurrent state entries are NOT pools — their leading
        axes are (slots, ...), not (pages, ...) — and pass through
        untouched (sharing is rejected for state-bearing stacks anyway;
        the per-kind dispatch keeps that a local fact, not a load-bearing
        one)."""
        kinds, tail = self._kinds, self._tail
        state = set(api.STATE_KINDS)

        def cow(cache, src, dst):
            def cp_scan(pool):              # (L, P, pg, ..)
                return pool.at[:, dst].set(pool[:, src])

            def cp_tail(pool):              # (P, pg, ..)
                return pool.at[dst].set(pool[src])

            new_scan = {}
            for j, kd in enumerate(kinds):
                e = cache["scan"].get(str(j))
                if e is None:
                    continue
                new_scan[str(j)] = e if kd in state \
                    else jax.tree.map(cp_scan, e)
            new_tail = [e if kd in state else jax.tree.map(cp_tail, e)
                        for e, kd in zip(cache["tail"], tail)]
            return {"scan": new_scan, "tail": new_tail}

        return cow

    def _make_pool_gather(self, kinds_ok: set):
        """Host-tier D2H staging: gather ``pages``'s rows out of every
        page-pool layer whose kind is in ``kinds_ok`` into a detached blob
        tree (dict-keyed tail so entry indices survive the round-trip).
        Pages are padded to a power of two with SCRATCH (bounds trace
        count; the padded rows carry scratch garbage and scatter back onto
        the scratch page). Dtypes pass through — int8 pools swap bitwise."""
        kinds, tail = self._kinds, self._tail

        def gather(cache, pages):
            def g_scan(leaf):           # (L,P,pg,..) -> (L,n,pg,..)
                return jnp.take(leaf, pages, axis=1)

            def g_tail(leaf):           # (P,pg,..) -> (n,pg,..)
                return jnp.take(leaf, pages, axis=0)

            return {
                "scan": {str(j): jax.tree.map(g_scan, cache["scan"][str(j)])
                         for j, kd in enumerate(kinds)
                         if kd in kinds_ok and str(j) in cache["scan"]},
                "tail": {str(i): jax.tree.map(g_tail, e)
                         for i, (e, kd) in enumerate(zip(cache["tail"],
                                                         tail))
                         if kd in kinds_ok},
            }

        return gather

    def _make_pool_scatter(self, kinds_ok: set):
        """Host-tier H2D landing: write a gathered blob back into fresh
        ``pages`` of every matching pool layer (the promote half of the
        demote/promote round trip). Layers outside ``kinds_ok`` pass
        through untouched."""
        kinds, tail = self._kinds, self._tail

        def scatter(cache, pages, blob):
            def s_scan(pool, b):        # (L,P,pg,..) <- (L,n,pg,..)
                return pool.at[:, pages].set(b.astype(pool.dtype))

            def s_tail(pool, b):        # (P,pg,..) <- (n,pg,..)
                return pool.at[pages].set(b.astype(pool.dtype))

            new_scan = {}
            for j, kd in enumerate(kinds):
                e = cache["scan"].get(str(j))
                if e is None:
                    continue
                new_scan[str(j)] = jax.tree.map(
                    s_scan, e, blob["scan"][str(j)]) \
                    if kd in kinds_ok else e
            new_tail = [jax.tree.map(s_tail, e, blob["tail"][str(i)])
                        if kd in kinds_ok else e
                        for i, (e, kd) in enumerate(zip(cache["tail"],
                                                        tail))]
            return {"scan": new_scan, "tail": new_tail}

        return scatter

    def _pad_pages(self, pages) -> jax.Array:
        """Page vector padded to a power of two with SCRATCH, so the
        gather/scatter programs trace once per size class, not once per
        page count (the prefill-bucket trick applied to swap IO)."""
        n = _next_pow2(max(1, len(pages)))
        out = np.full((n,), SCRATCH_PAGE, np.int32)
        out[: len(pages)] = pages
        return jnp.asarray(out)

    def _prefill_for(self, bucket) -> None:
        """jax.jit's shape cache gives one trace per bucket (plain bucket
        int for whole-prompt prefill, (suffix_bucket, prefix_pages) pairs
        for the shared path). The seen-bucket set drives the counter."""
        if bucket not in self._seen_buckets:
            self._seen_buckets.add(bucket)
            self.prefill_traces += 1

    # -- host-side engine -------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.live):
            if r is None:
                return i
        return None

    def _bucket(self, n: int) -> int:
        return min(max(self.page_size, _next_pow2(n)), self.max_len)

    def win_pages_bound(self, n_tokens: int) -> int:
        """Max simultaneous live window pages while serving ``n_tokens``:
        the window plus one in-flight write block (which is spec_k + 1
        tokens wide under speculative decode) can straddle
        ceil((window + T)/page) + 1 pages; fewer if the request never
        grows that long."""
        t_block = self.spec_k + 1
        return min(self.alloc.pages_for(n_tokens),
                   -(-(self.window + t_block) // self.page_size) + 1)

    def _worst_case_pages(self, n_tokens: int) -> int:
        """Pages a request can ever hold at once (admission feasibility)."""
        need = 0
        if self.has_full:
            need += self.alloc.pages_for(n_tokens)
        if self.has_win:
            need += self.win_pages_bound(n_tokens)
        return need

    def _submit(self, req: Request) -> bool:
        """Prefill `req` into a free slot. False if out of slots or pages
        (admission rejection — never corrupts a live neighbor's pages).

        With the prefix cache on, admission first takes the longest cached
        prefix (whole pages shared by refcount, plus at most one partial
        page duplicated copy-on-write), prefills only the remaining
        suffix, and afterwards publishes the request's own full prompt
        pages into the radix tree for the next arrival to reuse."""
        slot = self._free_slot()
        if slot is None:
            return False
        if self.tier is not None and self.tier.has_swap(req.rid):
            # swapped-out request: resume by promoting its pages + state
            # back from the host tier — no re-prefill. Runs BEFORE the
            # reject-as-done guard below on purpose: a request that was
            # live when preempted always satisfies it (pos <= max_len - 2,
            # generation budget left), and the guard's re-prefill footprint
            # math doesn't describe a swap-in.
            with self.trace.span("swap_in"):
                return self._swap_in(req, slot)
        toks = list(req.prompt) + list(req.generated)   # resume-on-preempt
        L = len(toks)
        remaining = req.max_new - len(req.generated)
        # decode stops at max_len-1 regardless of max_new, so the worst-
        # case footprint is bounded by max_len tokens (windowed tables by
        # O(window) pages — recycling keeps them there)
        worst = min(L + remaining, self.max_len)
        if (L >= self.max_len - 1 or remaining <= 0
                or self._worst_case_pages(worst) > self.alloc.num_pages):
            # can't (or needn't) ever serve this request: drop it as done
            # with whatever it has, rather than crash the loop or let the
            # scheduler retry an admission that can never succeed
            req.done = True
            self._note_finished(req.rid)
            return True

        shared: List[int] = []
        partial_page, partial_tokens = None, 0
        m = None
        if self.prefix is not None:
            # cap at L-1: at least one token must be prefilled — its logits
            # pick the next token, a pure cache hit has none to offer
            m = self.prefix.match(toks, max_tokens=L - 1)
            if self.tier is not None:
                # hits on host-resident radix nodes: promote them back to
                # device pages (H2D, prefetched a tick ahead when the
                # scheduler showed us this request) instead of letting the
                # match silently shrink to the device-resident prefix
                with self.trace.span("promote_match"):
                    m = self._promote_match(m)
            shared = m.pages
            partial_page, partial_tokens = m.partial_page, m.partial_tokens
        need_fresh = (self.alloc.pages_for(L) - len(shared)
                      if self.has_full else 0)
        deficit = need_fresh - self.alloc.free_pages
        if deficit > 0 and self.prefix is not None:
            # shed idle cached pages before rejecting admission — but
            # only if shedding can actually cover the deficit: flushing
            # still-matchable prefixes ahead of a rejection that happens
            # anyway would cost every future hit and buy nothing. The
            # match's own pages are not yet refcounted, so shield them.
            # With the host tier on, "shed" means demote (the node stays
            # matchable), and any idle node qualifies — not just leaves.
            keep = set(shared)
            if partial_page is not None:
                keep.add(partial_page)
            can = (self.prefix.demotable_count(keep) if self.tier is not None
                   else self.prefix.evictable_count(protect=keep))
            if can >= deficit:
                self._shed_idle_cache(deficit, protect=keep)
        table: List[int] = []
        if self.has_full:
            got = self.alloc.allocate_shared(req.rid, L, shared)
            if got is None:
                return False         # pool full: reject admission
            table = got
        wtable: List[int] = []
        dead0 = 0
        if self.has_win:
            # a prompt longer than the window admits with its pre-window
            # blocks never allocated (base_blocks): future queries sit at
            # positions >= L and can only see keys > L - window
            dead0 = min(max(0, L - self.window + 1) // self.page_size,
                        self.alloc.pages_for(L) - 1)
            got = self.alloc.allocate(_win_rid(req.rid), L,
                                      base_blocks=dead0)
            if got is None:
                if self.has_full:
                    self.alloc.free_request(req.rid)
                return False         # pool full: reject admission
            wtable = got
        if m is not None:
            # admission is now certain: count the lookup and touch the
            # matched path's LRU clock (a rejected-and-retried submit must
            # not inflate hit rates or keep its prefix hot)
            self.prefix.commit(m, L)
        prefix_len = len(shared) * self.page_size + partial_tokens
        if partial_page is not None:
            # the request diverges INSIDE a cached page: duplicate it into
            # the request's fresh page (rows < partial_tokens are reused,
            # the rest is overwritten by the suffix prefill below)
            dst = table[len(shared)]
            self.cache = self._cow_fn(self.cache, jnp.int32(partial_page),
                                      jnp.int32(dst))
            self.cow_copies += 1
            self.trace.instant("cow_copy", tid="prefix")

        row = np.zeros((self.max_blocks,), np.int32)
        row[: len(table)] = table
        # sliding-window device row: logical block j of [dead0, dead0+n)
        # holds wtable[j - dead0]; everything else (recycled lead blocks,
        # never-written tail) stays SCRATCH
        row_win = np.zeros((self.max_blocks,), np.int32)
        row_win[dead0: dead0 + len(wtable)] = wtable
        # the prefill's own draw decides generated-token index
        # len(req.generated) (> 0 on preemption-resume: the fold replays
        # the identical token the unpreempted run drew there)
        pol_req = request_params(req, self.default_params)
        pol = policy_operands([pol_req], [req.rid], [len(req.generated)],
                              self.seed)
        if prefix_len == 0:
            bucket = self._bucket(L)
            nb = bucket // self.page_size
            pages = np.full((nb,), SCRATCH_PAGE, np.int32)
            pages[: len(table)] = table[:nb]
            pages_win = np.full((nb,), SCRATCH_PAGE, np.int32)
            pages_win[dead0: min(dead0 + len(wtable), nb)] = \
                wtable[: max(0, nb - dead0)]
            tok_arr = np.zeros((1, bucket), np.int32)
            tok_arr[0, :L] = toks
            self._prefill_for(bucket)
            tr = self.trace
            with tr.span("prefill_dispatch",
                         args={"bucket": bucket} if tr else None):
                (self.cache, self.block_table, self.win_table, self.pos,
                 self.cur_tok, self.live_mask, self.gen_cnt,
                 self.max_new_arr, tok) = self._prefill_fn(
                    self.params, self.cache, self.block_table,
                    self.win_table, self.pos, self.cur_tok, self.live_mask,
                    self.gen_cnt, self.max_new_arr, jnp.asarray(tok_arr),
                    jnp.int32(L), jnp.asarray(pages),
                    jnp.asarray(pages_win), jnp.asarray(row),
                    jnp.asarray(row_win), jnp.int32(slot),
                    jnp.int32(remaining), pol)
            self.prefilled_tokens += L
        else:
            suffix = toks[prefix_len:]
            bucket = self._bucket(len(suffix))
            # prefix pages to gather: the shared full pages plus the CoW'd
            # partial page, padded to a power of two (bounds trace count;
            # scratch-padded rows sit past every real position and are
            # causally masked)
            n_pref = len(shared) + (1 if partial_page is not None else 0)
            npb = min(_next_pow2(n_pref), self.max_blocks)
            pages = np.full((npb,), SCRATCH_PAGE, np.int32)
            pages[:n_pref] = table[:n_pref]
            # physical (page, row) of every suffix token; bucket padding
            # lands on the scratch page
            phys = np.full((bucket,), SCRATCH_PAGE, np.int32)
            rows = np.zeros((bucket,), np.int32)
            for t in range(bucket):
                ab = prefix_len + t
                rows[t] = ab % self.page_size
                if ab < L:
                    phys[t] = table[ab // self.page_size]
            tok_arr = np.zeros((1, bucket), np.int32)
            tok_arr[0, : len(suffix)] = suffix
            self._prefill_for(("shared", bucket, npb))
            tr = self.trace
            with tr.span("prefill_dispatch",
                         args={"bucket": bucket, "shared": prefix_len}
                         if tr else None):
                (self.cache, self.block_table, self.pos, self.cur_tok,
                 self.live_mask, self.gen_cnt, self.max_new_arr,
                 tok) = self._prefill_shared_fn(
                    self.params, self.cache, self.block_table, self.pos,
                    self.cur_tok, self.live_mask, self.gen_cnt,
                    self.max_new_arr, jnp.asarray(tok_arr),
                    jnp.int32(len(suffix)), jnp.asarray(pages),
                    jnp.int32(prefix_len), jnp.asarray(phys),
                    jnp.asarray(rows), jnp.asarray(row), jnp.int32(slot),
                    jnp.int32(remaining), pol)
            self.prefilled_tokens += len(suffix)
        self.prompt_tokens += L
        if self.prefix is not None:
            # publish the prompt's full pages for future arrivals (before
            # the finish check: even a request that completes at prefill
            # seeds the cache — its pages survive via the tree's pin)
            self.prefix.insert(toks, table)

        self.live[slot] = req
        self._pos_host[slot] = L
        self._policy[slot] = pol_req
        self._rid_host[slot] = req.rid
        self._admit_counter += 1
        self._admit_seq[slot] = self._admit_counter
        t = int(tok)
        first = req.rid not in self.first_token_at
        req.generated.append(t)
        self._samp_idx[slot] = len(req.generated)
        self._note_emitted(req.rid)
        if first:
            if pol_req.is_greedy:
                self.greedy_requests += 1
            else:
                self.sampled_requests += 1
        self._count_tokens(pol_req, 1)
        if (t == self.eos_id or len(req.generated) >= req.max_new):
            self._finish_slot(slot)
        return True

    def _release_slot(self, slot: int) -> Request:
        """Reclaim a slot's pages; the slot's table becomes all-scratch so
        a dead slot can only ever write to the scratch page."""
        req = self.live[slot]
        self.live[slot] = None
        self._policy[slot] = None
        if self.has_full:
            self.alloc.free_request(req.rid)
            self.block_table = self.block_table.at[slot].set(SCRATCH_PAGE)
        if self.has_win:
            self.alloc.free_request(_win_rid(req.rid))
            self.win_table = self.win_table.at[slot].set(SCRATCH_PAGE)
        self.live_mask = self.live_mask.at[slot].set(False)
        if self.drafter is not None:
            # the drafter's private context cache for this request is
            # stale the moment the slot releases (finish or preemption —
            # a resumed request re-ingests)
            self.drafter.drop(req.rid)
        return req

    def _finish_slot(self, slot: int) -> None:
        req = self._release_slot(slot)
        req.done = True
        self._note_finished(req.rid)

    def _evict_slot(self, slot: int) -> Request:
        """Preempt destructively: reclaim pages, return the request for
        re-admission (it resumes by re-prefilling prompt +
        generated-so-far). With the host tier on, ``_swap_out_slot`` is
        the preferred path — this survives as its overflow fallback."""
        req = self._release_slot(slot)
        req.preemptions += 1
        return req

    def _reclaim_one_page(self, keep_slot: int,
                          preempted: List[Request]) -> bool:
        """Free at least one page for `keep_slot`: first shed an idle
        cached page (demote-or-evict — costs at most one future promote
        or re-prefill), only then preempt the youngest other live request
        (swap-out when the tier is on; destructive re-prefill preemption
        otherwise). False if neither source has anything left."""
        if self._shed_idle_cache(1):
            return True
        victims = [s for s, r in enumerate(self.live)
                   if r is not None and s != keep_slot]
        if not victims:
            return False
        youngest = max(victims, key=lambda s: self._admit_seq[s])
        if self.tier is not None:
            with self.trace.span("swap_out"):
                preempted.append(self._swap_out_slot(youngest))
        else:
            self.trace.instant("preempt")
            preempted.append(self._evict_slot(youngest))
        return True

    # -- host tier: demote / promote / swap --------------------------------

    def _shed_idle_cache(self, n_pages: int,
                         protect: Optional[set] = None) -> int:
        """Free ``n_pages`` device pages from the idle prefix cache. Tier
        off: plain LRU eviction. Tier on: demote first — gather the page's
        KV (one-page blobs: the page-granular half of the mixed-grained
        streamer), hand it to the host store and free the device page
        while the node stays matchable — falling back to eviction only
        when the host store refuses (capacity cap). Returns pages freed."""
        if self.prefix is None:
            return 0
        if self.tier is None:
            return self.prefix.evict(n_pages, protect=protect)
        freed = 0
        for node in self.prefix.demotable(protect):
            if freed >= n_pages:
                break
            blob = self._gather_full(self.cache,
                                     self._pad_pages([node.page]))
            if not self.tier.can_accept(_tree_nbytes(blob)):
                break                # host store full: evict the rest
            handle = self.tier.store.put(blob)
            self.prefix.demote_node(node, handle)
            self.tier.cache_demotions += 1
            self.tier.demoted_pages += 1
            freed += 1
        if freed < n_pages:
            freed += self.prefix.evict(n_pages - freed, protect=protect)
        return freed

    def _promote_match(self, m: PrefixMatch) -> PrefixMatch:
        """Promote every host-resident node on a match's path back to a
        fresh pinned device page (H2D through the copy stream — a hit
        when the scheduler's prefetch hook saw this prompt last tick).
        If the pool can't supply a page mid-path, the match truncates at
        that node: the pages BELOW the cut are already promoted and
        usable, everything above re-prefills."""
        for i, node in enumerate(m.path):
            if node.page is not None:
                m.pages[i] = node.page     # promoted by an earlier caller
                continue
            page = self.alloc.alloc_pinned_page()
            if page is None:
                return PrefixMatch(
                    m.pages[:i], i * self.page_size,
                    node=m.path[i - 1] if i else None, path=m.path[:i])
            handle = node.host
            blob = self.tier.stream.take(handle)
            self.cache = self._scatter_full(self.cache,
                                            self._pad_pages([page]), blob)
            self.tier.store.pop(handle)
            self.prefix.promote_node(node, page)
            self.tier.cache_promotions += 1
            self.tier.promoted_pages += 1
            m.pages[i] = page
        return m

    def _swap_out_slot(self, slot: int) -> Request:
        """Preempt WITHOUT destroying work: gather the slot's full and
        window tables into host blobs (request-granular), export its
        recurrent state slots, then demote the allocator bookkeeping and
        free the device pages — gather-then-free is safe under JAX
        dispatch ordering. Falls back to destructive eviction when the
        host store refuses the bytes (counted, loud)."""
        req = self.live[slot]
        tier = self.tier
        rec = SwapRecord(rid=req.rid, pos=self._pos_host[slot])
        blobs = {}
        if self.has_full:
            table = self.alloc.block_table(req.rid)
            rec.full_pages = len(table)
            blobs["full"] = self._gather_full(self.cache,
                                              self._pad_pages(table))
        if self.has_win:
            wrid = _win_rid(req.rid)
            wtable = self.alloc.block_table(wrid)
            rec.win_pages = len(wtable)
            rec.win_base = self.alloc.base_blocks(wrid)
            blobs["win"] = self._gather_win(self.cache,
                                            self._pad_pages(wtable))
        if self.has_state:
            blobs["state"] = self._state_export_fn(self.cache,
                                                   jnp.int32(slot))
        if not tier.can_accept(sum(_tree_nbytes(b) for b in blobs.values())):
            return self._evict_slot(slot)
        for name, blob in blobs.items():
            setattr(rec, name, tier.store.put(blob))
        if self.has_full:
            self.alloc.demote(req.rid)
            self.block_table = self.block_table.at[slot].set(SCRATCH_PAGE)
        if self.has_win:
            self.alloc.demote(_win_rid(req.rid))
            self.win_table = self.win_table.at[slot].set(SCRATCH_PAGE)
        tier.demoted_pages += rec.full_pages + rec.win_pages
        self.live[slot] = None
        self._policy[slot] = None
        self.live_mask = self.live_mask.at[slot].set(False)
        if self.drafter is not None:
            self.drafter.drop(req.rid)
        tier.record_swap(rec)
        req.preemptions += 1
        return req

    def _swap_in(self, req: Request, slot: int) -> bool:
        """Resume a swapped-out request: promote its allocator tables,
        scatter the host blobs into the fresh pages (the copy stream
        already has them in flight when the prefetch hook fired), import
        its recurrent state, and rebuild the slot bookkeeping exactly
        where the preemption left it — pos, current token, remaining
        generation budget. NO tokens are prefilled and none are emitted.
        False (request keeps waiting) if the pool can't host it yet —
        a swap-in never preempts someone else (anti-thrash)."""
        tier = self.tier
        rec = tier.peek_swap(req.rid)
        need = 0
        if self.has_full:
            need += self.alloc.host_pages_needed(req.rid)
        if self.has_win:
            need += self.alloc.host_pages_needed(_win_rid(req.rid))
        deficit = need - self.alloc.free_pages
        if deficit > 0:
            self._shed_idle_cache(deficit)
            if need > self.alloc.free_pages:
                return False
        table: List[int] = []
        if self.has_full:
            table = self.alloc.promote(req.rid)
            assert table is not None
        wtable: List[int] = []
        if self.has_win:
            wtable = self.alloc.promote(_win_rid(req.rid))
            assert wtable is not None
        if rec.full is not None:
            blob = tier.stream.take(rec.full)
            self.cache = self._scatter_full(self.cache,
                                            self._pad_pages(table), blob)
        if rec.win is not None:
            blob = tier.stream.take(rec.win)
            self.cache = self._scatter_win(self.cache,
                                           self._pad_pages(wtable), blob)
        if rec.state is not None:
            self.cache = self._state_import_fn(
                self.cache, jnp.int32(slot), tier.stream.take(rec.state))
        tier.promoted_pages += len(table) + len(wtable)
        row = np.zeros((self.max_blocks,), np.int32)
        row[: len(table)] = table
        self.block_table = self.block_table.at[slot].set(jnp.asarray(row))
        row_win = np.zeros((self.max_blocks,), np.int32)
        row_win[rec.win_base: rec.win_base + len(wtable)] = wtable
        self.win_table = self.win_table.at[slot].set(jnp.asarray(row_win))
        self.pos = self.pos.at[slot].set(rec.pos)
        self.cur_tok = self.cur_tok.at[slot, 0].set(int(req.generated[-1]))
        self.live_mask = self.live_mask.at[slot].set(True)
        # gen restarts at 1 with a rebased budget, exactly the re-prefill
        # resume's accounting: done when total generated reaches max_new
        self.gen_cnt = self.gen_cnt.at[slot].set(1)
        self.max_new_arr = self.max_new_arr.at[slot].set(
            req.max_new - len(req.generated) + 1)
        self.live[slot] = req
        self._pos_host[slot] = rec.pos
        self._policy[slot] = request_params(req, self.default_params)
        self._rid_host[slot] = req.rid
        # the next draw decides generated-token index len(generated) —
        # the same fold the unpreempted run would have used there
        self._samp_idx[slot] = len(req.generated)
        self._admit_counter += 1
        self._admit_seq[slot] = self._admit_counter
        tier.pop_swap(req.rid)
        return True

    def prefetch_pending(self, pending: List[Request]) -> None:
        """The streamer's look-ahead (Scheduler.tick calls this with the
        queue snapshot between admission and decode): start the H2D
        copies that NEXT tick's admissions will consume — request-
        granular for swapped-out requests (their whole swap set), page-
        granular for prompts whose radix match crosses host-resident
        nodes — so they overlap this tick's decode step."""
        if self.tier is None:
            return
        for req in pending:
            if self.tier.has_swap(req.rid):
                for h in self.tier.peek_swap(req.rid).handles():
                    self.tier.stream.prefetch(h)
            elif self.prefix is not None:
                toks = list(req.prompt) + list(req.generated)
                m = self.prefix.match(toks, max_tokens=len(toks) - 1)
                for node in m.path:
                    if node.page is None:
                        self.tier.stream.prefetch(node.host)

    def tier_stats(self) -> Dict[str, float]:
        """Host-tier telemetry. The key set is identical whether the tier
        is on or off (``HostTier.zero_stats`` fills zeros) so downstream
        CSV columns never shift with configuration."""
        d: Dict[str, float] = {"host_tier": float(self.tier is not None)}
        d.update(self.tier.stats() if self.tier is not None
                 else HostTier.zero_stats())
        return d

    def ensure_decode_capacity(self, n_tokens: int = 1) -> List[Request]:
        """Allocate the pages the next decode step will write into
        (allocate-on-demand). ``n_tokens`` > 1 provisions a speculative
        verify block's WHOLE write range — positions pos .. pos+n_tokens-1,
        capped at max_len — so a multi-token step can never write an
        unallocated page (rows past max_len are redirected to scratch by
        the model layer and their logits discarded by the max_len stop).
        On pool exhaustion, evict idle prefix-cache pages first, then
        preempt the youngest live requests until the remaining ones fit.
        Returns preempted requests (resubmit them to resume). Also
        enforces the write-exclusivity invariant over the whole write
        range: every page the step may write must be privately owned — if
        one is shared (refcount > 1: another table or the radix tree
        references it), it is duplicated copy-on-write first."""
        preempted: List[Request] = []
        page = self.page_size
        for slot in sorted((s for s, r in enumerate(self.live)
                            if r is not None),
                           key=lambda s: self._admit_seq[s]):
            req = self.live[slot]
            if req is None:
                continue
            pos = self._pos_host[slot]
            target = min(pos + n_tokens, self.max_len)
            if self.has_win:
                # recycle window pages FIRST: blocks entirely below every
                # future query's window (< pos - window + 1) free pages
                # this very top-up may need — that recycling is what
                # bounds a windowed request at O(window) live pages
                self._recycle_win(slot, req.rid, pos)
                self._grow_table(_win_rid(req.rid), slot, target,
                                 preempted, win=True)
                self._recycle_win(slot, req.rid, pos)
            if self.has_full:
                self._grow_table(req.rid, slot, target, preempted,
                                 win=False)
                # write exclusivity across every block the step may touch
                # (only the first — the partially-written one — can
                # actually be shared; the loop is the defensive spelling;
                # window pages are never shared, so full tables only)
                for blk in range(pos // page, (target - 1) // page + 1):
                    while self.alloc.ref(
                            self.alloc.block_table(req.rid)[blk]) > 1:
                        swapped = self.alloc.replace_page(req.rid, blk)
                        if swapped is not None:
                            src, dst = swapped
                            self.cache = self._cow_fn(self.cache,
                                                      jnp.int32(src),
                                                      jnp.int32(dst))
                            self.block_table = self.block_table.at[
                                slot, blk].set(dst)
                            self.cow_copies += 1
                            break
                        if not self._reclaim_one_page(slot, preempted):
                            raise RuntimeError(
                                "page pool too small for a single request")
        return preempted

    def _grow_table(self, rid, slot: int, target: int,
                    preempted: List[Request], *, win: bool) -> None:
        """Grow ``rid``'s table page-by-page until it covers ``target``
        tokens (extend_to grows at most one page per call), publishing
        fresh pages to the matching device table and reclaiming (evict /
        preempt-youngest) on pool exhaustion."""
        page = self.page_size
        while True:
            have = (self.alloc.base_blocks(rid)
                    + len(self.alloc.block_table(rid))) * page
            got = self.alloc.extend_to(rid, min(target, have + page))
            if got is None:
                if not self._reclaim_one_page(slot, preempted):
                    raise RuntimeError(
                        "page pool too small for a single request")
                continue
            if got:              # fresh page: publish to device table
                if win:
                    self.win_table = self.win_table.at[
                        slot, have // page].set(got)
                else:
                    self.block_table = self.block_table.at[
                        slot, have // page].set(got)
            if have + page >= target or not got:
                break

    def _recycle_win(self, slot: int, rid: int, pos: int) -> None:
        """Free this slot's window pages that slid entirely below the
        attention window: every query from here on sits at a position
        >= ``pos``, so keys at positions <= pos - window can never be
        read again. Their logical blocks go back to SCRATCH on device
        (the kernel skips them) and their pages back to the free list
        (PageAllocator.release_prefix). At least one block always stays
        (the one being written)."""
        wrid = _win_rid(rid)
        dead = max(0, pos - self.window + 1) // self.page_size
        base = self.alloc.base_blocks(wrid)
        n = min(dead - base, len(self.alloc.block_table(wrid)) - 1)
        if n > 0:
            if self.tier is not None:
                # demotion source 3: archive the slid-out blocks (capped)
                # before recycling — raw material for hybrid prefix
                # caching (ROADMAP open 5), gathered while the pages are
                # still live, freed right after (dispatch-order safe)
                pages = self.alloc.block_table(wrid)[:n]
                blob = self._gather_win(self.cache, self._pad_pages(pages))
                if self.tier.can_accept(_tree_nbytes(blob)):
                    self.tier.archive_window(rid, base, n,
                                             self.tier.store.put(blob))
            self.win_recycled_pages += self.alloc.release_prefix(wrid, n)
            self.win_table = self.win_table.at[
                slot, base:base + n].set(SCRATCH_PAGE)

    def _step(self) -> List[Request]:
        """Advance every live slot: one device program, one host sync.
        With spec_k > 0 this is a speculative verify step emitting up to
        spec_k + 1 tokens per request; otherwise the plain one-token step.
        Tops up the pages the step will write into first (a bare
        submit/step loop must never cross a page boundary unallocated —
        that write would land on the scratch page and silently corrupt
        the request); returns any requests preempted by that top-up, for
        the caller to resubmit. (Callers use ``step()`` — the mixin's
        timed wrapper.)"""
        tr = self.trace
        if self.tier is not None:
            # the copy-stream contract's visibility point: pending D2H
            # copies finalize exactly once per decode tick
            with tr.span("tier_drain"):
                self.tier.drain()
        if self.spec_k:
            return self._step_speculative()
        if not any(r is not None for r in self.live):
            return []
        with tr.span("ensure_capacity"):
            evicted = self.ensure_decode_capacity()
        pol = policy_operands(self._policy, self._rid_host,
                              self._samp_idx, self.seed)
        t0 = time.perf_counter()
        with tr.span("device_dispatch"):
            (self.cache, self.cur_tok, self.pos, self.gen_cnt,
             self.live_mask, done_d, toks_d) = self._step_fn(
                self.params, self.cache, self.block_table, self.win_table,
                self.cur_tok, self.pos, self.live_mask, self.gen_cnt,
                self.max_new_arr, pol)
        with tr.span("host_sync"):
            # repro-lint: disable=host-sync — THE one blessed sync per step
            toks, done = jax.device_get((toks_d, done_d))
        self.step_wall_s += time.perf_counter() - t0
        self.decode_steps += 1
        for i, r in enumerate(self.live):
            if r is None:
                continue
            r.generated.append(int(toks[i]))
            self._pos_host[i] += 1
            self._samp_idx[i] += 1
            self.decoded_tokens += 1
            self._count_tokens(self._policy[i], 1)
            self._note_emitted(r.rid)
            if done[i]:
                self._finish_slot(i)
        if tr:
            tr.counter("pool_pages", {
                "allocated": float(self.alloc.allocated_pages),
                "free": float(self.alloc.free_pages)})
        return evicted

    def _step_speculative(self) -> List[Request]:
        """One speculative verify step. Per live slot: draft up to spec_k
        tokens (the configured ``drafter``'s model, or prompt lookup over
        the request's OWN context — host-side, no second model), score
        [current token, drafts...] as a T = spec_k + 1 row block in one
        multi-token page sweep, rejection-sample the drafts against the
        slot's decode policy (row t accepts its draft w.p.
        ``min(1, p(draft)/q(draft))`` — ``u < p(draft)`` for our
        deterministic drafters; exact prefix match at temperature 0),
        emit the accepted prefix plus one more token (the residual sample
        after the first rejection, or a full bonus sample after row
        n_draft — so even an all-miss step emits exactly the plain step's
        token, and marginally every emitted token is distributed as a
        non-speculative sample; see runtime/sampling.py), then roll
        position and pages back past the accept point (truncate_to: whole
        pages the rejected rows provisioned are disowned; rejected rows
        inside a kept page are dead rows masked by the request length and
        overwritten by the next step). At temperature 0 this is the exact
        greedy verification it generalizes: outputs equal the T=1
        engine's token-for-token."""
        if not any(r is not None for r in self.live):
            return []
        tr = self.trace
        T = self.spec_k + 1
        with tr.span("ensure_capacity"):
            evicted = self.ensure_decode_capacity(T)
        t0 = time.perf_counter()
        tok_block = np.zeros((self.slots, T), np.int32)
        n_draft = [0] * self.slots
        with tr.span("draft"):
            for s, r in enumerate(self.live):
                if r is None:
                    continue
                ctx = r.prompt + r.generated
                tok_block[s, 0] = ctx[-1]  # current token, not yet in cache
                if self.drafter is not None:
                    d = self.drafter.propose(r.rid, ctx,
                                             self.spec_k)[: self.spec_k]
                else:
                    d = ngram_propose(ctx, self.spec_k,
                                      max_ngram=self.spec_ngram)
                tok_block[s, 1:1 + len(d)] = d
                n_draft[s] = len(d)
                self.spec_drafted += len(d)
                self.spec_slot_steps += 1
        pol = policy_operands(self._policy, self._rid_host,
                              self._samp_idx, self.seed)
        with tr.span("device_dispatch"):
            self.cache, acc_d, emit_d = self._spec_fn(
                self.params, self.cache, self.block_table, self.win_table,
                jnp.asarray(tok_block),
                jnp.asarray(self._pos_host, jnp.int32),
                jnp.asarray(n_draft, jnp.int32), pol)
        with tr.span("host_sync"):
            # repro-lint: disable=host-sync — the verify step's one sync
            accept, emit = jax.device_get((acc_d, emit_d))  # 1 host sync
        self.step_wall_s += time.perf_counter() - t0
        self.decode_steps += 1
        with tr.span("accept_rollback"):
            survivors = []        # (slot, new_pos, emitted, cur_tok) rows
            accept_idx = np.zeros((self.slots,), np.int32)
            for s, r in enumerate(self.live):
                if r is None:
                    continue
                pos0 = self._pos_host[s]
                a = 0                      # accepted drafts
                while a < n_draft[s] and accept[s, a]:
                    a += 1
                # accepted drafts verbatim, then row a's sample (residual
                # after a rejection, full after the last accepted draft)
                emitted = [int(tok_block[s, j + 1]) for j in range(a)]
                emitted.append(int(emit[s, a]))
                # emit, applying the T=1 stop conditions in emission
                # order (eos / generation budget / context cap) — tokens
                # past the first stop are discarded, exactly as the plain
                # engine would never have produced them
                finished = False
                m = 0
                for j, t in enumerate(emitted):
                    r.generated.append(t)
                    m += 1
                    self.decoded_tokens += 1
                    if (t == self.eos_id or len(r.generated) >= r.max_new
                            or pos0 + j + 1 >= self.max_len - 1):
                        finished = True
                        break
                self.spec_accepted += m - 1
                accept_idx[s] = m - 1      # recurrent state after row m-1
                self._samp_idx[s] += m
                self._count_tokens(self._policy[s], m)
                self._note_emitted(r.rid, m)
                if finished:
                    self._finish_slot(s)   # frees every page incl. drafts
                    continue
                # rollback: disown the whole pages past the accept point
                # and republish their table slots as scratch on device —
                # full and window tables alike (a rejected row may have
                # crossed a page boundary in either)
                if self.has_full:
                    dropped = self.alloc.truncate_to(r.rid, pos0 + m)
                    if dropped:
                        keep = len(self.alloc.block_table(r.rid))
                        self.block_table = self.block_table.at[
                            s, keep:keep + dropped].set(SCRATCH_PAGE)
                if self.has_win:
                    wrid = _win_rid(r.rid)
                    dropped = self.alloc.truncate_to(wrid, pos0 + m)
                    if dropped:
                        keep = (self.alloc.base_blocks(wrid)
                                + len(self.alloc.block_table(wrid)))
                        self.win_table = self.win_table.at[
                            s, keep:keep + dropped].set(SCRATCH_PAGE)
                self._pos_host[s] = pos0 + m
                survivors.append((s, pos0 + m, m, int(r.generated[-1])))
            if self._select_fn is not None:
                # collapse the verify step's checkpointed recurrent states
                # (T axis) to each slot's accepted row — the state-slot
                # analogue of the page rollback above. Must run even when
                # every slot finished: the next step's trace expects plain
                # state shapes.
                self.cache = self._select_fn(self.cache,
                                             jnp.asarray(accept_idx))
            if survivors:
                # device mirrors (pos / gen / cur_tok) stay in sync — so
                # telemetry and a switch back to the T=1 path keep working
                # — via ONE batched update per array per step, not one
                # dispatch per slot
                idx = np.array([u[0] for u in survivors])
                self.pos = self.pos.at[idx].set(
                    np.array([u[1] for u in survivors], np.int32))
                self.gen_cnt = self.gen_cnt.at[idx].add(
                    np.array([u[2] for u in survivors], np.int32))
                self.cur_tok = self.cur_tok.at[idx, 0].set(
                    np.array([u[3] for u in survivors], np.int32))
        return evicted

    def spec_stats(self) -> Dict[str, float]:
        """Speculative-decode telemetry: draft volume, acceptance rate,
        and the headline number — tokens emitted per request per verify
        step (the plain engine is 1.0 per request-step by construction;
        the gap above 1.0 is decode wall-clock won at unchanged per-step
        page traffic)."""
        return {
            "spec_k": float(self.spec_k),
            "spec_drafted": float(self.spec_drafted),
            "spec_accepted": float(self.spec_accepted),
            "accept_rate": (self.spec_accepted / self.spec_drafted
                            if self.spec_drafted else 0.0),
            "accepted_per_step": (self.decoded_tokens / self.spec_slot_steps
                                  if self.spec_slot_steps else 1.0),
            "drafter": ("none" if not self.spec_k
                        else (self.drafter.kind if self.drafter is not None
                              else "ngram")),
        }

    def sampling_stats(self) -> Dict[str, float]:
        """Decode-policy telemetry (ISSUE 9): the greedy/sampled request
        and token mix, the jit trace counts the mixed-batch acceptance
        criterion asserts on (``step_traces`` / ``spec_traces`` — like
        ``prefill_traces`` these are lifetime facts that survive
        ``reset_metrics``), and the draft-model drafter's counters
        (zeros when no model drafter is attached, so the key set is
        engine- and configuration-stable)."""
        d = {
            "greedy_requests": float(self.greedy_requests),
            "sampled_requests": float(self.sampled_requests),
            "greedy_tokens": float(self.greedy_tokens),
            "sampled_tokens": float(self.sampled_tokens),
            "step_traces": float(self.step_traces),
            "spec_traces": float(self.spec_traces),
            "draft_proposed": 0.0,
            "draft_ingested_tokens": 0.0,
            "draft_decode_calls": 0.0,
            "draft_pool_rejects": 0.0,
        }
        if self.drafter is not None:
            d.update(self.drafter.stats())
        return d

    def has_live(self) -> bool:
        return any(r is not None for r in self.live)

    def pool_stats(self) -> PoolStats:
        return PoolStats.of(self.alloc, self.slots, self.max_len)

    def shard_stats(self) -> Dict[str, float]:
        """Per-shard telemetry for the TP engine (meaningful, if boring,
        on the single-shard engine too). Pages are allocated logically —
        host-side, shard-agnostic — and the block table is replicated, so
        every shard holds the SAME page set; what tensor parallelism
        divides is each page's bytes (a shard owns its KV-head slice of
        every page). ``peak_pages_per_shard`` is therefore the allocator's
        peak, and the per-shard byte number is what shrinks with M."""
        m = self.tp.model_shards if self.tp is not None else 1
        sharded_axes = sorted(self.tp.sharded_axes) if self.tp else []
        spec_leaves = None
        if self.tp is not None:
            spec_leaves = jax.tree.leaves(
                self._cache_specs, is_leaf=lambda x: isinstance(x, P))
        per_shard = 0
        for i, leaf in enumerate(jax.tree.leaves(self.cache)):
            nbytes = leaf.size * leaf.dtype.itemsize
            if spec_leaves is not None and _spec_uses(spec_leaves[i],
                                                      "model"):
                nbytes //= m
            per_shard += nbytes
        return {
            "model_shards": float(m),
            # "+"-joined, not ","-joined: this string lands in CSV cells
            "sharded_axes": "+".join(sharded_axes),
            "peak_pages_per_shard": float(self.alloc.peak_pages),
            "pool_bytes_per_shard": float(per_shard),
        }

    def prefix_stats(self) -> Dict[str, float]:
        """Prefix-sharing telemetry: token-level hit rate, prefill compute
        avoided, CoW traffic. Meaningful (non-zero) only with the prefix
        cache enabled; the prefill counters are kept either way so the
        no-sharing engine reports a comparable baseline."""
        d = {
            "prompt_tokens": float(self.prompt_tokens),
            "prefilled_tokens": float(self.prefilled_tokens),
            "prefill_tokens_saved": float(self.prompt_tokens
                                          - self.prefilled_tokens),
            "prefill_saved_frac": ((self.prompt_tokens
                                    - self.prefilled_tokens)
                                   / self.prompt_tokens
                                   if self.prompt_tokens else 0.0),
            "cow_copies": float(self.cow_copies),
        }
        d.update(self.prefix.stats() if self.prefix is not None
                 else PrefixCache.zero_stats())
        return d

    def _reset_subsystem_counters(self) -> None:
        """reset_metrics() tail: zero the paged engine's own telemetry and
        every enabled subsystem's counters (allocator peaks rebase to the
        current allocation; radix/tier contents survive — only rates
        reset)."""
        self.prompt_tokens = 0
        self.prefilled_tokens = 0
        self.cow_copies = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_slot_steps = 0
        self.win_recycled_pages = 0
        # step_traces / spec_traces deliberately survive (lifetime facts,
        # like prefill_traces — see reset_metrics)
        self.greedy_requests = 0
        self.sampled_requests = 0
        self.greedy_tokens = 0
        self.sampled_tokens = 0
        self.alloc.peak_pages = self.alloc.allocated_pages
        self.alloc.share_events = 0
        if self.prefix is not None:
            self.prefix.reset_hit_counters()
        if self.tier is not None:
            self.tier.reset_counters()

    def check(self) -> None:
        """Engine-level pool invariants: the allocator's shared-page-aware
        check() plus write exclusivity — the block each live request's
        next token lands in must not be shared (refcount 1), or the next
        decode step would scribble over another reader's KV."""
        self.alloc.check()
        for slot, req in enumerate(self.live):
            if req is None:
                continue
            if self.has_full:
                table = self.alloc.block_table(req.rid)
                blk = self._pos_host[slot] // self.page_size
                if blk < len(table):
                    assert self.alloc.ref(table[blk]) == 1, (
                        f"slot {slot}: next-write page {table[blk]} is "
                        f"shared (ref {self.alloc.ref(table[blk])})")
            if self.has_win:
                wrid = _win_rid(req.rid)
                live = len(self.alloc.block_table(wrid))
                bound = self.win_pages_bound(self.max_len)
                assert live <= bound, (
                    f"slot {slot}: {live} live window pages exceed the "
                    f"O(window) bound {bound} — recycling fell behind")

    def run_to_completion(self, requests: List[Request],
                          max_steps: int = 10_000) -> List[Request]:
        return _run_to_completion(self, requests, max_steps)


# ===========================================================================
# Dense-slot engine (seed baseline; serves recurrent stacks)
# ===========================================================================


class DenseServingEngine(ServingMetricsMixin):
    """Fixed-slot batch: each slot owns a dense max_len cache lane. Kept as
    the measured baseline for the paged engine and as the serving path for
    stacks with recurrent state. Retraces prefill per distinct prompt
    length and syncs the host once per live slot per step."""

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 rules: Rules = NO_RULES, eos_id: int = -1,
                 temperature: float = 0.0, seed: int = 0,
                 sampling: Optional[SamplingParams] = None,
                 tracer: Optional[Tracer] = None):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.rules, self.eos_id = rules, eos_id
        # engine-wide default policy; per-request Request.params override
        # it (same resolution as the paged engine — the two must agree
        # for the dense-vs-paged equivalence baselines to hold)
        self.default_params = (sampling if sampling is not None
                               else SamplingParams(
                                   temperature=temperature)).validate()
        self.temperature = self.default_params.temperature
        self.seed = int(seed) & 0x7FFFFFFF
        self._init_metrics(tracer)    # tracer + shared latency counters
        self.cache = api.cache_init(cfg, slots, max_len)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur_tok = jnp.zeros((slots, 1), jnp.int32)
        self.live: List[Optional[Request]] = [None] * slots
        self._policy: List[Optional[SamplingParams]] = [None] * slots
        self._rid_host = [0] * slots
        self._samp_idx = [0] * slots
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos,
                                                 rules=rules))
        self._prefill = jax.jit(
            lambda p, b: api.prefill(cfg, p, b, rules=rules,
                                     max_len=max_len))

        def _samp(logits, pol):
            # trace-time increment: one count per compiled logit shape
            # (decode's (slots, V) + prefill's (1, V)), NOT per policy
            # value — policies are operands, so a mixed greedy+sampled
            # batch reuses the same trace (the ISSUE 9 criterion)
            # repro-lint: disable=retrace-hazard — counting traces IS the point
            self.step_traces += 1
            return sample_rows(logits[..., : cfg.vocab], pol)

        self._sample_fn = jax.jit(_samp)
        self.step_traces = 0
        self.spec_traces = 0          # dense engine has no verify step
        self.greedy_requests = 0
        self.sampled_requests = 0
        self.greedy_tokens = 0
        self.sampled_tokens = 0
        self._seen_lengths: set = set()
        self.prompt_tokens = 0
        self.prefilled_tokens = 0     # == prompt_tokens (no sharing here)

    @property
    def prefill_traces(self) -> int:
        return len(self._seen_lengths)

    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.live):
            if r is None:
                return i
        return None

    def _submit(self, req: Request) -> bool:
        """Prefill `req` and install it into a free slot. False if full."""
        slot = self._free_slot()
        if slot is None:
            return False
        # the paged engine's reject-as-done guard (see PagedServingEngine.
        # submit): a prompt over the lane length would either break the
        # dynamic_update_slice cache merge below (prefill cache longer
        # than the lane) or silently clamp-overwrite the last KV row
        # (attention_decode's dense write lands at min(pos, S-1)), and a
        # request with no generation budget left can never emit — drop
        # them as done with whatever they have instead of corrupting a
        # lane or letting the scheduler retry an admission that can never
        # succeed. The threshold is deliberately the PAGED engine's
        # (>= max_len - 1, one token stricter than the dense lane strictly
        # needs): both engines must agree on which requests are servable,
        # or the dense-vs-paged equivalence baselines diverge on traces
        # that contain a boundary-length prompt.
        if (len(req.prompt) >= self.max_len - 1
                or req.max_new - len(req.generated) <= 0):
            req.done = True
            self._note_finished(req.rid)
            return True
        self._seen_lengths.add(len(req.prompt))
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        tr = self.trace
        pol_req = request_params(req, self.default_params)
        pol = policy_operands([pol_req], [req.rid],
                              [len(req.generated)], self.seed)
        with tr.span("prefill_dispatch",
                     args={"len": len(req.prompt)} if tr else None):
            last_logits, cache1, pos1 = self._prefill(self.params,
                                                      {"tokens": toks})
        tok = self._sample_fn(last_logits, pol)[0]
        first = req.rid not in self.first_token_at
        req.generated.append(int(tok))
        self._policy[slot] = pol_req
        self._rid_host[slot] = req.rid
        self._samp_idx[slot] = len(req.generated)
        if first:
            if pol_req.is_greedy:
                self.greedy_requests += 1
            else:
                self.sampled_requests += 1
        self._count_tokens(pol_req, 1)
        self.prompt_tokens += len(req.prompt)
        self.prefilled_tokens += len(req.prompt)
        self._note_emitted(req.rid)
        # merge the B=1 cache lane into slot `slot` of the batched cache
        self.cache = jax.tree.map(
            lambda big, one: jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), slot,
                axis=_batch_axis(big, one)),
            self.cache, cache1)
        self.pos = self.pos.at[slot].set(int(pos1[0]))
        self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
        self.live[slot] = req
        return True

    def _step(self) -> List[Request]:
        """Advance every live slot one token. Returns [] (dense lanes are
        statically reserved, so a step never preempts). Callers use
        ``step()`` — the mixin's timed wrapper."""
        if not any(r is not None for r in self.live):
            return []
        tr = self.trace
        pol = policy_operands(self._policy, self._rid_host,
                              self._samp_idx, self.seed)
        t0 = time.perf_counter()
        with tr.span("device_dispatch"):
            logits, self.cache = self._decode(self.params, self.cache,
                                              self.cur_tok, self.pos)
            toks = self._sample_fn(logits, pol)
            self.pos = self.pos + jnp.asarray(
                [1 if r is not None else 0 for r in self.live], jnp.int32)
            self.cur_tok = toks[:, None]
        with tr.span("host_sync"):
            # repro-lint: disable=host-sync — the dense step's one timed sync
            jax.block_until_ready(toks)  # keep the sync inside the timer
        self.step_wall_s += time.perf_counter() - t0
        self.decode_steps += 1
        for i, r in enumerate(self.live):
            if r is None:
                continue
            t = int(toks[i])
            r.generated.append(t)
            self._samp_idx[i] += 1
            self.decoded_tokens += 1
            self._count_tokens(self._policy[i], 1)
            self._note_emitted(r.rid)
            if (t == self.eos_id or len(r.generated) >= r.max_new
                    or int(self.pos[i]) >= self.max_len - 1):
                r.done = True
                self.live[i] = None
                self._policy[i] = None
                self._note_finished(r.rid)
        return []

    def has_live(self) -> bool:
        return any(r is not None for r in self.live)

    def ensure_decode_capacity(self) -> List[Request]:
        return []                     # dense lanes never run out mid-flight

    # -- stats: the PAGED key sets, zero-filled (stable metrics() keys) ----

    def pool_stats(self) -> PoolStats:
        """Dense lanes are statically reserved — there is no pool. The
        zeros keep ``metrics()``'s key set identical to the paged
        engine's; ``dense_equiv_tokens`` reports the reservation that a
        paged pool would be measured against."""
        return PoolStats(page_size=0, num_pages=0, allocated_pages=0,
                         peak_pages=0, live_tokens=0, utilization=0.0,
                         dense_equiv_tokens=self.slots * self.max_len)

    def spec_stats(self) -> Dict[str, float]:
        return {"spec_k": 0.0, "spec_drafted": 0.0, "spec_accepted": 0.0,
                "accept_rate": 0.0, "accepted_per_step": 1.0,
                "drafter": "none"}

    def sampling_stats(self) -> Dict[str, float]:
        """Paged engine's key set, zero-filled where dense has no
        counterpart (no drafter, no verify step)."""
        return {
            "greedy_requests": float(self.greedy_requests),
            "sampled_requests": float(self.sampled_requests),
            "greedy_tokens": float(self.greedy_tokens),
            "sampled_tokens": float(self.sampled_tokens),
            "step_traces": float(self.step_traces),
            "spec_traces": float(self.spec_traces),
            "draft_proposed": 0.0,
            "draft_ingested_tokens": 0.0,
            "draft_decode_calls": 0.0,
            "draft_pool_rejects": 0.0,
        }

    def prefix_stats(self) -> Dict[str, float]:
        d = {
            "prompt_tokens": float(self.prompt_tokens),
            "prefilled_tokens": float(self.prefilled_tokens),
            "prefill_tokens_saved": 0.0,
            "prefill_saved_frac": 0.0,
            "cow_copies": 0.0,
        }
        d.update(PrefixCache.zero_stats())
        return d

    def tier_stats(self) -> Dict[str, float]:
        d: Dict[str, float] = {"host_tier": 0.0}
        d.update(HostTier.zero_stats())
        return d

    def shard_stats(self) -> Dict[str, float]:
        per = sum(leaf.size * leaf.dtype.itemsize
                  for leaf in jax.tree.leaves(self.cache))
        return {"model_shards": 1.0, "sharded_axes": "",
                "peak_pages_per_shard": 0.0,
                "pool_bytes_per_shard": float(per)}

    def _reset_subsystem_counters(self) -> None:
        self.prompt_tokens = 0
        self.prefilled_tokens = 0
        # step_traces survives (lifetime fact, like prefill_traces)
        self.greedy_requests = 0
        self.sampled_requests = 0
        self.greedy_tokens = 0
        self.sampled_tokens = 0

    def run_to_completion(self, requests: List[Request],
                          max_steps: int = 10_000) -> List[Request]:
        # the bug class PR 3 fixed in Scheduler.drain, which this
        # engine's private loop used to reintroduce by truncating
        # silently on budget exhaustion
        return _run_to_completion(self, requests, max_steps)


def _batch_axis(big, one) -> int:
    """Find the batch axis: first axis where shapes differ (slots vs 1)."""
    for ax, (b, o) in enumerate(zip(big.shape, one.shape)):
        if b != o:
            return ax
    return 0
