"""Serving engine: batched prefill/decode with slot-based continuous batching.

The engine owns a fixed-slot batch (like vLLM's static batch mode): each slot
holds one request's cache lane. `submit` prefills a prompt (B=1) and merges
its cache into the slot; `step` advances every live slot one token; finished
slots free automatically. Greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.parallel.sharding import NO_RULES, Rules


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 rules: Rules = NO_RULES, eos_id: int = -1,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.rules, self.eos_id = rules, eos_id
        self.temperature = temperature
        self.key = jax.random.key(seed)
        self.cache = api.cache_init(cfg, slots, max_len)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur_tok = jnp.zeros((slots, 1), jnp.int32)
        self.live: List[Optional[Request]] = [None] * slots
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode_step(cfg, p, c, t, pos,
                                                 rules=rules))
        self._prefill = jax.jit(
            lambda p, b: api.prefill(cfg, p, b, rules=rules,
                                     max_len=max_len))

    # ------------------------------------------------------------------
    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.live):
            if r is None:
                return i
        return None

    def submit(self, req: Request) -> bool:
        """Prefill `req` and install it into a free slot. False if full."""
        slot = self._free_slot()
        if slot is None:
            return False
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        last_logits, cache1, pos1 = self._prefill(self.params,
                                                  {"tokens": toks})
        tok = self._sample(last_logits)[0]
        req.generated.append(int(tok))
        # merge the B=1 cache lane into slot `slot` of the batched cache
        self.cache = jax.tree.map(
            lambda big, one: jax.lax.dynamic_update_slice_in_dim(
                big, one.astype(big.dtype), slot,
                axis=_batch_axis(big, one)),
            self.cache, cache1)
        self.pos = self.pos.at[slot].set(int(pos1[0]))
        self.cur_tok = self.cur_tok.at[slot, 0].set(tok)
        self.live[slot] = req
        return True

    def _sample(self, logits) -> jax.Array:
        logits = logits[..., : self.cfg.vocab]
        if self.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(
            k, logits / self.temperature, -1).astype(jnp.int32)

    def step(self) -> None:
        """Advance every live slot one token."""
        if not any(r is not None for r in self.live):
            return
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.cur_tok, self.pos)
        toks = self._sample(logits)
        self.pos = self.pos + jnp.asarray(
            [1 if r is not None else 0 for r in self.live], jnp.int32)
        self.cur_tok = toks[:, None]
        for i, r in enumerate(self.live):
            if r is None:
                continue
            t = int(toks[i])
            r.generated.append(t)
            if (t == self.eos_id or len(r.generated) >= r.max_new
                    or int(self.pos[i]) >= self.max_len - 1):
                r.done = True
                self.live[i] = None

    def run_to_completion(self, requests: List[Request],
                          max_steps: int = 10_000) -> List[Request]:
        pending = list(requests)
        done: List[Request] = []
        steps = 0
        while (pending or any(r is not None for r in self.live)) \
                and steps < max_steps:
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
            done = [r for r in requests if r.done]
        return done


def _batch_axis(big, one) -> int:
    """Find the batch axis: first axis where shapes differ (slots vs 1)."""
    for ax, (b, o) in enumerate(zip(big.shape, one.shape)):
        if b != o:
            return ax
    return 0
