"""Near-zero-overhead serving tracer with Chrome Trace Event export.

The paper's headline numbers are *utilization measurements*: the data
streamers' 2.12-2.94x temporal-utilization win is only claimable because
the authors could see per-cycle compute-vs-stall breakdowns (PAPER.md,
Fig. 6). This module is the serving-level analogue of that measurement
infrastructure: every phase of the request lifecycle and decode tick
(admission, prefill, draft, device dispatch, host sync, host-tier copy
traffic, rollback) records a span, and the result exports as Chrome
Trace Event Format JSON — loadable in Perfetto (https://ui.perfetto.dev)
or ``chrome://tracing`` — so "why was this tick slow" is a timeline
query, not a print-statement archaeology session.

Design constraints, in order:

* **Disabled is free.** ``Tracer(enabled=False)`` (and the shared
  module-level ``NULL_TRACER``) allocates NOTHING per call: ``span()``
  returns one process-wide no-op context manager, ``instant``/
  ``counter``/``begin_async`` return immediately. Engines hold a tracer
  unconditionally; the hot decode loop pays one attribute load and one
  predictable branch per phase.
* **Bounded memory.** Events land in a ring buffer (``capacity``
  events, drop-oldest). Dropping old COMPLETE events can never corrupt
  nesting: a span is recorded at exit, so an enclosing span is always
  *younger* in the buffer than everything it encloses — evicting oldest
  evicts innermost/earliest first. The dropped count is exported under
  ``otherData.dropped_events`` so a truncated trace says so.
* **Host-clock only.** Timestamps are ``time.perf_counter_ns`` µs
  relative to tracer creation. Device-side async work appears as the
  host-visible dispatch/sync spans around it (the same one-host-sync
  contract the engines already measure with ``step_wall_s``).

Span taxonomy (tids group the timeline rows; see DESIGN.md
"Observability" for the full map):

* tid ``engine``  — ``admit`` (prefill path: ``prefix_match``,
  ``prefill_dispatch``), ``decode_tick`` (``tier_drain``,
  ``ensure_capacity``, ``draft``, ``device_dispatch``, ``host_sync``,
  ``accept_rollback``), ``swap_in`` / ``swap_out`` / ``promote_match``.
* tid ``sched``   — ``tick`` (``admit_loop``, ``prefetch``).
* tid ``tier``    — ``d2h_finalize``, ``h2d_demand_fetch`` (the copy-
  stream *stall*: a consumer whose prefetch never started), instants
  ``h2d_prefetch`` / ``h2d_hit``.
* tid ``prefix``  — ``match``, ``evict``, instants ``insert``.
* tid ``router``  — instants ``dispatch`` (per-replica routing).
* cat ``request`` — async ``b``/``e`` pairs per request id (lifecycle:
  enqueue -> done) with ``first_token`` instants, so per-request latency
  reads directly off the timeline.

Usage::

    tr = Tracer(enabled=True)
    with tr.span("decode_tick"):
        with tr.span("device_dispatch"):
            ...
    tr.export("trace.json")           # open in Perfetto

Validation: ``validate_trace(obj)`` checks the schema (ph/ts/dur/
pid/tid fields, per-tid span nesting, async pairing) and is exposed as
``python -m repro.runtime.trace --validate trace.json`` for the CI gate
over the bench-smoke trace artifact.

Pure host-side stdlib module: no jax imports, safe everywhere.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple


class _NoopSpan:
    """The shared do-nothing context manager a disabled tracer returns."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class _Span:
    """A live span: records one Chrome 'complete' (ph=X) event on exit."""
    __slots__ = ("_tr", "name", "cat", "tid", "args", "_t0")

    def __init__(self, tr: "Tracer", name: str, cat: str, tid: int,
                 args: Optional[Dict[str, Any]]):
        self._tr = tr
        self.name, self.cat, self.tid, self.args = name, cat, tid, args

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        tr = self._tr
        ev = {"name": self.name, "cat": self.cat, "ph": "X",
              "ts": (self._t0 - tr._epoch) // 1000,
              "dur": (t1 - self._t0) // 1000,
              "pid": tr.pid, "tid": self.tid}
        if self.args:
            ev["args"] = self.args
        tr._push(ev)
        return False


class Tracer:
    """Bounded-ring-buffer span/counter recorder with Chrome-trace export.

    ``enabled=False`` is the hot-path no-op mode: every recording method
    returns immediately (``span`` hands back the shared ``NOOP_SPAN``),
    nothing is allocated, and ``bool(tracer)`` is False so callers can
    guard arg-dict construction with ``if tr:``.
    """

    def __init__(self, *, enabled: bool = True, capacity: int = 1 << 18,
                 pid: int = 0, process_name: str = "repro-serve"):
        assert capacity >= 1
        self.enabled = enabled
        self.capacity = capacity
        self.pid = pid
        self.process_name = process_name
        self._epoch = time.perf_counter_ns()
        self._events: deque = deque(maxlen=capacity)
        self.events_recorded = 0             # lifetime (incl. dropped)
        self._tids: Dict[str, int] = {}      # thread name -> tid int

    # -- recording --------------------------------------------------------

    def __bool__(self) -> bool:
        return self.enabled

    def _push(self, ev: Dict[str, Any]) -> None:
        self._events.append(ev)
        self.events_recorded += 1

    def _now(self) -> int:
        return (time.perf_counter_ns() - self._epoch) // 1000

    def _tid(self, name: str) -> int:
        tid = self._tids.get(name)
        if tid is None:
            tid = self._tids[name] = len(self._tids)
        return tid

    def span(self, name: str, *, tid: str = "engine", cat: str = "serve",
             args: Optional[Dict[str, Any]] = None):
        """Context manager timing a phase (Chrome 'complete' event).
        Spans on the same tid must nest (context-manager discipline in
        single-threaded host code gives this for free)."""
        if not self.enabled:
            return NOOP_SPAN
        return _Span(self, name, cat, self._tid(tid), args)

    def instant(self, name: str, *, tid: str = "engine",
                cat: str = "serve",
                args: Optional[Dict[str, Any]] = None) -> None:
        """A zero-duration marker (Chrome 'instant' event, thread scope)."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self._now(), "pid": self.pid, "tid": self._tid(tid)}
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, values: Dict[str, float], *,
                tid: str = "engine", cat: str = "serve") -> None:
        """A monotonic/utilization counter sample (Chrome 'C' event);
        Perfetto renders each key in ``values`` as a track series."""
        if not self.enabled:
            return
        self._push({"name": name, "cat": cat, "ph": "C",
                    "ts": self._now(), "pid": self.pid,
                    "tid": self._tid(tid), "args": values})

    def begin_async(self, name: str, aid, *, cat: str = "request",
                    args: Optional[Dict[str, Any]] = None) -> None:
        """Open an async interval (ph 'b') — request lifecycles span many
        ticks and interleave, which synchronous spans cannot express."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "b", "id": str(aid),
              "ts": self._now(), "pid": self.pid,
              "tid": self._tid("requests")}
        if args:
            ev["args"] = args
        self._push(ev)

    def end_async(self, name: str, aid, *, cat: str = "request",
                  args: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "e", "id": str(aid),
              "ts": self._now(), "pid": self.pid,
              "tid": self._tid("requests")}
        if args:
            ev["args"] = args
        self._push(ev)

    # -- inspection / export ----------------------------------------------

    @property
    def dropped_events(self) -> int:
        return self.events_recorded - len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    def clear(self) -> None:
        self._events.clear()
        self.events_recorded = 0

    def phase_walls(self) -> Dict[str, Tuple[int, float]]:
        """Aggregate wall time by span name: ``{name: (count, secs)}``,
        sorted by total descending. Nested spans overlap their parents
        (``decode_tick`` contains ``device_dispatch``), so rows are a
        breakdown to read top-down, not a partition that sums to 1."""
        acc: Dict[str, List[float]] = {}
        for ev in self._events:
            if ev.get("ph") != "X":
                continue
            c = acc.setdefault(ev["name"], [0, 0.0])
            c[0] += 1
            c[1] += ev["dur"] / 1e6
        return {k: (int(v[0]), v[1]) for k, v in
                sorted(acc.items(), key=lambda kv: -kv[1][1])}

    def format_phase_walls(self, prefix: str = "  ") -> str:
        lines = [f"{prefix}{name:<22s} {n:>7d} x {secs:>9.4f} s"
                 for name, (n, secs) in self.phase_walls().items()]
        return "\n".join(lines) if lines else f"{prefix}(no spans recorded)"

    def to_dict(self) -> Dict[str, Any]:
        """The Chrome Trace Event Format object: ring-buffer events plus
        process/thread-name metadata rows so Perfetto labels the tracks."""
        meta: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": self.process_name}}]
        for tname, tid in self._tids.items():
            meta.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                         "tid": tid, "args": {"name": tname}})
        return {
            "traceEvents": meta + list(self._events),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped_events,
                          "events_recorded": self.events_recorded},
        }

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)


#: The process-wide disabled tracer every engine defaults to.
NULL_TRACER = Tracer(enabled=False, capacity=1)

_default: Tracer = NULL_TRACER


def set_default_tracer(tracer: Optional[Tracer]) -> None:
    """Install the tracer engines pick up when built without an explicit
    ``tracer=`` (benchmark harness / launcher convenience: one call turns
    on tracing for every engine a scenario constructs). ``None`` restores
    the disabled ``NULL_TRACER``."""
    global _default
    _default = tracer if tracer is not None else NULL_TRACER


def default_tracer() -> Tracer:
    return _default


# -- percentiles (metrics helpers; host-side, no numpy dependency) --------

def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank-with-interpolation percentile; 0.0 on empty input so
    metric key sets stay stable when nothing was measured."""
    if not xs:
        return 0.0
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    pos = (len(s) - 1) * q
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


# -- schema validation (the CI gate over exported traces) -----------------

_VALID_PH = {"X", "B", "E", "i", "I", "C", "M", "b", "e", "n", "s", "t",
             "f"}


def validate_trace(obj: Any) -> List[str]:
    """Validate a Chrome Trace Event Format object (the JSON-object
    flavor Perfetto and chrome://tracing load). Returns violations
    (empty list = valid):

    * top level must be ``{"traceEvents": [...]}``;
    * every event needs ``ph`` (known phase) and ``pid``; non-metadata
      events need integer ``ts`` >= 0 and ``tid``; ``X`` events need
      integer ``dur`` >= 0 and a ``name``;
    * per (pid, tid), ``X`` spans must NEST — two spans may share a
      timeline row only if one contains the other or they are disjoint;
    * async ``b``/``e`` events need ``id`` + ``cat``; an ``e`` without a
      prior ``b`` for its (cat, id, name) is flagged — unless the trace
      declares dropped events (ring-buffer eviction removes the oldest
      ``b`` rows first, legitimately orphaning their ``e``).
    """
    errors: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' array"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be an array"]
    dropped = 0
    other = obj.get("otherData")
    if isinstance(other, dict):
        dropped = int(other.get("dropped_events", 0) or 0)

    spans: Dict[Tuple[Any, Any], List[Tuple[int, int, str]]] = {}
    async_open: Dict[Tuple[Any, Any, Any], int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errors.append(f"event {i}: unknown ph {ph!r}")
            continue
        if "pid" not in ev:
            errors.append(f"event {i} (ph={ph}): missing pid")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, int) or ts < 0:
            errors.append(f"event {i} (ph={ph}): ts must be a "
                          f"non-negative integer, got {ts!r}")
            continue
        if "tid" not in ev:
            errors.append(f"event {i} (ph={ph}): missing tid")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, int) or dur < 0:
                errors.append(f"event {i}: X event dur must be a "
                              f"non-negative integer, got {dur!r}")
                continue
            if not ev.get("name"):
                errors.append(f"event {i}: X event missing name")
                continue
            spans.setdefault((ev.get("pid"), ev["tid"]), []).append(
                (ts, dur, ev["name"]))
        elif ph == "C":
            if not isinstance(ev.get("args"), dict):
                errors.append(f"event {i}: counter event needs an args "
                              f"object of series values")
        elif ph in ("b", "e", "n"):
            if "id" not in ev or "cat" not in ev:
                errors.append(f"event {i}: async {ph} event needs id "
                              f"and cat")
                continue
            key = (ev["cat"], ev["id"], ev.get("name"))
            if ph == "b":
                async_open[key] = async_open.get(key, 0) + 1
            elif ph == "e":
                if async_open.get(key, 0) > 0:
                    async_open[key] -= 1
                elif dropped == 0:
                    errors.append(
                        f"event {i}: async end without matching begin "
                        f"for {key} (and no dropped events declared)")

    # per-track nesting: sweep spans by (start, -dur) and keep a stack of
    # open intervals — a span starting inside the top must also end
    # inside it
    for (pid, tid), ivs in spans.items():
        ivs.sort(key=lambda x: (x[0], -x[1]))
        stack: List[Tuple[int, int, str]] = []
        for ts, dur, name in ivs:
            while stack and ts >= stack[-1][0] + stack[-1][1]:
                stack.pop()
            if stack and ts + dur > stack[-1][0] + stack[-1][1]:
                top = stack[-1]
                errors.append(
                    f"tid {tid} (pid {pid}): span {name!r} "
                    f"[{ts}, {ts + dur}) partially overlaps "
                    f"{top[2]!r} [{top[0]}, {top[0] + top[1]}) — spans "
                    f"on one track must nest")
                continue
            stack.append((ts, dur, name))
    return errors


def _main() -> None:
    import argparse
    import sys
    ap = argparse.ArgumentParser(
        description="Validate a Chrome Trace Event JSON file (the CI "
                    "gate over serve_bench --trace-out artifacts)")
    ap.add_argument("trace", help="trace JSON file to validate")
    ap.add_argument("--validate", action="store_true",
                    help="(default and only mode; kept for readability "
                         "at the call site)")
    args = ap.parse_args()
    with open(args.trace) as f:
        obj = json.load(f)
    errors = validate_trace(obj)
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        raise SystemExit(1)
    events = obj["traceEvents"]
    n_spans = sum(1 for e in events if isinstance(e, dict)
                  and e.get("ph") == "X")
    names = sorted({e["name"] for e in events if isinstance(e, dict)
                    and e.get("ph") == "X"})
    print(f"validate_trace: OK — {len(events)} events, {n_spans} spans "
          f"({', '.join(names[:12])}{'...' if len(names) > 12 else ''})")


if __name__ == "__main__":
    _main()
