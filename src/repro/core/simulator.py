"""End-to-end Voltra simulator: per-layer latency + energy — Fig. 6(c),
Fig. 7(b)/(d), Table I.

Latency accounting (the paper's): utilization metrics are measured within
tiled layer blocks; *total latency* additionally counts the DMA cycles of
tile movement over the whole execution. We report both the serial
(compute + DMA) and the double-buffer-overlapped (max(compute, DMA))
composition; Fig. 6(c) uses the serial one, matching the paper's separate
"GEMM core computation cycles" vs "DMA data movement cycles" bars.

Configurations:
  * voltra      — shared memory + MGDP prefetching + PDMA tiling (the chip)
  * separated   — fixed per-operand buffers, dedicated dispatchers: no bank
                  contention (higher temporal utilization — as the paper
                  notes) but naive, buffer-capped tiling (more DMA)
  * plain_shared— shared memory without MGDP (Fig. 6(b) baseline)

Energy: E = MACs*e_mac + SRAM_bytes*e_sram + DRAM_bytes*e_dram + P_static*t,
dynamic terms scaled by (V/Vref)^2; constants calibrated so the modeled
system power reproduces the paper's measured 171 mW @0.6 V/300 MHz and
981 mW @1.0 V/800 MHz on the dense 96^3 GEMM (see accel.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core import temporal, tiling
from repro.core.accel import VOLTRA, VoltraConfig
from repro.core.spatial import spatial_cycles
from repro.core.workloads import Op, Workload


@dataclasses.dataclass
class Stats:
    cycles_compute: float = 0.0
    cycles_dma: float = 0.0
    dram_bytes: float = 0.0
    sram_bytes: float = 0.0
    macs: float = 0.0

    @property
    def latency_serial(self) -> float:
        return self.cycles_compute + self.cycles_dma

    @property
    def latency_overlap(self) -> float:
        return max(self.cycles_compute, self.cycles_dma)

    def add(self, o: "Stats") -> None:
        self.cycles_compute += o.cycles_compute
        self.cycles_dma += o.cycles_dma
        self.dram_bytes += o.dram_bytes
        self.sram_bytes += o.sram_bytes
        self.macs += o.macs


def _op_stats(op: Op, config: str, cfg: VoltraConfig) -> Stats:
    if config == "voltra":
        plan = tiling.plan_op(op, "shared", cfg=cfg)
        util = temporal.op_temporal_util(op, cfg=cfg, mgdp=True)
    elif config == "plain_shared":
        plan = tiling.plan_op(op, "shared", cfg=cfg)
        util = temporal.op_temporal_util(op, cfg=cfg, mgdp=False)
    elif config == "separated":
        plan = tiling.plan_op_naive_separated(op, cfg=cfg)
        # dedicated buffers + dispatchers: no bank contention; only the
        # quant-SIMD drain limit remains
        k = max(1, math.ceil(op.K / cfg.array_k))
        util = temporal._drain_limit(k)
    else:
        raise ValueError(config)

    ideal = spatial_cycles(op, cfg)      # already includes op.repeat
    compute = ideal / max(util, 1e-9)
    dma_bytes = plan.dma_total * op.repeat
    n_tiles = (math.ceil(op.M / plan.tm) * math.ceil(op.N / plan.tn)
               * math.ceil(op.K / plan.tk)) * op.repeat
    dma = dma_bytes / cfg.dma_bytes_per_cycle + cfg.dma_setup_cycles * max(
        1, n_tiles // 8)
    # SRAM traffic: streamer reads during compute + DMA writes into memory
    sram = (ideal * (cfg.input_demand + cfg.weight_demand)
            + op.bytes_out() * op.repeat + dma_bytes)
    return Stats(compute, dma, dma_bytes, sram, op.macs)


def simulate_workload(wl: Workload, config: str = "voltra",
                      cfg: VoltraConfig = VOLTRA) -> Stats:
    total = Stats()
    for op in wl.ops:
        total.add(_op_stats(op, config, cfg))
    return total


def latency_report(wl: Workload, cfg: VoltraConfig = VOLTRA) -> dict:
    """Fig. 6(c): total latency, Voltra (shared+PDMA) vs separated."""
    v = simulate_workload(wl, "voltra", cfg)
    s = simulate_workload(wl, "separated", cfg)
    return {
        "workload": wl.name,
        "voltra_compute_cycles": v.cycles_compute,
        "voltra_dma_cycles": v.cycles_dma,
        "separated_compute_cycles": s.cycles_compute,
        "separated_dma_cycles": s.cycles_dma,
        "gain_serial": s.latency_serial / v.latency_serial,
        "gain_overlap": s.latency_overlap / v.latency_overlap,
    }


# ---------------------------------------------------------------------------
# Energy / efficiency (Fig. 7, Table I)
# ---------------------------------------------------------------------------


def energy_pj(stats: Stats, *, vdd: float, cfg: VoltraConfig = VOLTRA,
              freq_mhz: Optional[float] = None) -> float:
    f = cfg.freq_at(vdd) if freq_mhz is None else freq_mhz
    vs = (vdd / cfg.vdd_ref) ** 2
    t_s = stats.latency_serial / (f * 1e6)
    return (stats.macs * cfg.e_mac_pj * vs
            + stats.sram_bytes * cfg.e_sram_pj_per_byte * vs
            + stats.dram_bytes * cfg.e_dram_pj_per_byte
            + cfg.p_static_mw * 1e9 * t_s)


def gemm_efficiency(M: int, K: int, N: int, *, vdd: float = 0.6,
                    cfg: VoltraConfig = VOLTRA,
                    preloaded: Optional[bool] = None) -> Dict[str, float]:
    """TOPS/W and sustained TOPS for a dense GEMM at a supply point
    (Fig. 7(b) uses M=N=K=96; Fig. 7(d) sweeps sizes).

    preloaded=True measures the steady-state kernel with operands resident
    in the shared memory (how a peak-efficiency point is measured on the
    chip: data loaded once, kernel iterated). Default: preloaded when the
    whole problem fits on-chip, streamed (DMA overlapped via the
    double-buffered streamers) otherwise.
    """
    wl = Workload(f"gemm{M}x{K}x{N}", (Op("g", M=M, K=K, N=N),))
    st = simulate_workload(wl, "voltra", cfg)
    if preloaded is None:
        preloaded = (M * K + K * N + M * N) <= cfg.mem_bytes
    f = cfg.freq_at(vdd)
    vs = (vdd / cfg.vdd_ref) ** 2
    if preloaded:
        cycles = st.cycles_compute
        dram = 0.0
    else:
        cycles = max(st.cycles_compute, st.cycles_dma)
        dram = st.dram_bytes
    t_s = cycles / (f * 1e6)
    e = (st.macs * cfg.e_mac_pj * vs
         + st.sram_bytes * cfg.e_sram_pj_per_byte * vs
         + dram * cfg.e_dram_pj_per_byte
         + cfg.p_static_mw * 1e9 * t_s)
    ops = 2.0 * st.macs
    return {
        "tops": ops / t_s / 1e12,
        "tops_per_w": ops / e,              # pJ -> ops/pJ == TOPS/W
        "power_mw": e / t_s * 1e-9,
        "vdd": vdd,
        "freq_mhz": f,
        "preloaded": float(preloaded),
    }


def sparsity_efficiency(M: int, K: int, N: int, *, weight_sparsity: float,
                        toggle_rate: float = 1.0, vdd: float = 0.6,
                        cfg: VoltraConfig = VOLTRA) -> float:
    """Fig. 7(c): effective TOPS/W under weight sparsity / input toggle
    rate. Voltra has no sparsity skipping logic — zero weights still take
    a cycle but toggle less datapath (dynamic MAC energy scales with the
    operand activity), which is why the paper reports rising efficiency
    with sparsity at constant throughput."""
    wl = Workload("g", (Op("g", M=M, K=K, N=N),))
    st = simulate_workload(wl, "voltra", cfg)
    f = cfg.freq_at(vdd)
    vs = (vdd / cfg.vdd_ref) ** 2
    activity = (1.0 - 0.7 * weight_sparsity) * (0.4 + 0.6 * toggle_rate)
    # steady-state kernel (operands preloaded), same basis as Fig. 7(b)
    e = (st.macs * cfg.e_mac_pj * vs * activity
         + st.sram_bytes * cfg.e_sram_pj_per_byte * vs
         + cfg.p_static_mw * 1e9 * st.cycles_compute / (f * 1e6))
    return 2.0 * st.macs / e


def table1(cfg: VoltraConfig = VOLTRA) -> Dict[str, float]:
    """Headline chip numbers (Table I / Fig. 5)."""
    lo = gemm_efficiency(96, 96, 96, vdd=cfg.vdd_min, cfg=cfg)
    hi = gemm_efficiency(96, 96, 96, vdd=cfg.vdd_max, cfg=cfg)
    area_mm2 = 0.654
    return {
        "macs": cfg.macs,
        "peak_tops": cfg.peak_tops(),                  # 0.8192 @ 800 MHz
        "peak_tops_per_w": lo["tops_per_w"],           # ~1.60 @ 0.6 V
        "power_mw_min": lo["power_mw"],                # ~171
        "power_mw_max": hi["power_mw"],                # ~981
        "area_eff_tops_mm2": cfg.peak_tops() / area_mm2,   # ~1.25
        "mem_kib": cfg.mem_kib,
    }
