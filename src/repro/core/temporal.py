"""Temporal-utilization model: shared-memory bank contention + MGDP — Fig. 6(b).

Two coupled models:

1. ``simulate_tile`` — a cycle-accurate event simulator of a run of output
   tiles: streamers issue per-beat bank requests against the 32-bank shared
   memory; each bank serves one 64-bit request per cycle; the GEMM core
   consumes one input beat + one weight beat per compute cycle.

   * mgdp=True  — streamers prefetch ahead through 8-deep FIFOs; the weight
     streamer fetches a 512-bit super-bank (one aligned 8-bank group as a
     single arbitration unit); input data has been laid out C/8HWC8 by the
     reshuffler so a beat's 8 channels hit 8 consecutive banks; the retire
     (quant-SIMD output) path drains asynchronously, overlapped with the
     next tile.
   * mgdp=False — the paper's plain-shared-memory baseline: no FIFOs. All
     of a beat's requests must be fetched synchronously; any bank conflict
     (within the beat or with the other operand / retire traffic) stalls
     the array. Conv inputs are strided (no blocked layout), landing on
     pseudo-random banks.

2. ``op_temporal_util`` — a closed-form approximation of the same machine
   (validated against the simulator in tests/test_temporal.py), used by
   the full-workload simulator:

   * plain: util = 1 / E[max per-bank load of the synchronous profile]
   * MGDP:  util = steady(rho, fifo_depth) — FIFO loss factor at the
     offered per-bank load

   Both sides are additionally capped by the quant-SIMD drain limit
   k/max(k, 8): the 8-lane time-multiplexed SIMD (Sec. II-D) needs 8
   cycles per 64-output tile, which only binds for very short K tiles
   (k_beats < 8, e.g. depthwise) — this is exactly why the paper measures
   just 0.7% SIMD loss on ResNet50 (K beats >= 72 there).
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import List, Optional

from repro.core.accel import VOLTRA, VoltraConfig
from repro.core.workloads import Op, Workload

_BEAT_REQS = 8          # 64-bit requests per 64-byte operand beat
_SIMD_CYCLES = 8        # 8-lane SIMD, 64 outputs per tile retire


class _LCG:
    """Deterministic pseudo-random bank offsets (no global RNG state)."""

    def __init__(self, seed: int):
        self.s = (seed ^ 0x9E3779B9) & 0xFFFFFFFF

    def next(self) -> int:
        self.s = (1103515245 * self.s + 12345) & 0x7FFFFFFF
        return self.s >> 7


@dataclasses.dataclass
class SimResult:
    compute_cycles: int
    total_cycles: int

    @property
    def util(self) -> float:
        return self.compute_cycles / max(self.total_cycles, 1)


def _beat_banks(idx: int, *, strided: bool, rng: _LCG, banks: int) -> List[int]:
    if strided:
        return [rng.next() % banks for _ in range(_BEAT_REQS)]
    base = (idx * _BEAT_REQS) % banks
    return [(base + j) % banks for j in range(_BEAT_REQS)]


class _Stream:
    """One streamer: AGU -> (FIFO) -> beats consumed by the core."""

    def __init__(self, name: str, *, depth: int, total_beats: int,
                 strided: bool, super_bank: bool, banks: int, seed: int):
        self.name = name
        self.depth = max(depth, 1)
        self.total = total_beats
        self.strided = strided
        self.super_bank = super_bank
        self.banks = banks
        self.rng = _LCG(seed)
        self.issued = 0          # beats whose requests have been generated
        self.done = 0            # beats fully fetched (in FIFO or consumed)
        self.consumed = 0
        self.pending: List[int] = []   # outstanding bank requests of 1 beat

    @property
    def occupancy(self) -> int:
        return self.done - self.consumed

    def want_issue(self) -> bool:
        inflight = self.issued - self.done
        return (not self.pending and self.issued < self.total
                and self.occupancy + inflight < self.depth)

    def issue(self) -> None:
        if self.super_bank:
            g = (self.issued % (self.banks // _BEAT_REQS))
            self.pending = [-(g + 1)]          # group token
        else:
            self.pending = _beat_banks(self.issued, strided=self.strided,
                                       rng=self.rng, banks=self.banks)
        self.issued += 1

    def arbitrate(self, busy: set) -> None:
        served = []
        for b in self.pending:
            if b < 0:
                grp = range((-b - 1) * _BEAT_REQS, (-b) * _BEAT_REQS)
                if all(x not in busy for x in grp):
                    busy.update(grp)
                    served.append(b)
            elif b not in busy:
                busy.add(b)
                served.append(b)
        for b in served:
            self.pending.remove(b)
        if not self.pending and self.done < self.issued:
            self.done += 1


def simulate_tile(k_beats: int, *, cfg: VoltraConfig = VOLTRA,
                  mgdp: bool = True, strided_input: bool = True,
                  n_tiles: int = 16, seed: int = 7) -> SimResult:
    """Simulate `n_tiles` consecutive output tiles of `k_beats` compute
    cycles each (one input + one weight beat per compute cycle), plus the
    retire (quant/output) traffic at each tile boundary."""
    B = cfg.num_banks
    depth = cfg.input_fifo_depth if mgdp else 1
    total = k_beats * n_tiles
    # MGDP: reshuffler guarantees blocked layout -> contiguous; plain keeps
    # the strided walk. GEMM workloads are contiguous either way.
    inp = _Stream("in", depth=depth, total_beats=total,
                  strided=strided_input and not mgdp,
                  super_bank=False, banks=B, seed=seed)
    wgt = _Stream("w", depth=depth, total_beats=total,
                  strided=False, super_bank=mgdp, banks=B, seed=seed + 1)
    retire_pending: List[int] = []
    retire_rng = _LCG(seed + 2)
    simd_free_at = 0

    compute = 0
    cycles = 0
    limit = 200 * total + 10_000
    while compute < total and cycles < limit:
        # issue
        for s in (inp, wgt):
            if s.want_issue():
                s.issue()
        # arbitration (input priority, then weight, then retire — psum-
        # before-output priority is inside the retire path)
        busy: set = set()
        inp.arbitrate(busy)
        wgt.arbitrate(busy)
        retire_pending = [b for b in retire_pending
                          if b in busy or (busy.add(b) or False)]

        # compute
        can_retire = True
        if not mgdp and retire_pending:
            can_retire = False        # plain: retire blocks the array
        if (inp.occupancy > 0 and wgt.occupancy > 0 and can_retire
                and cycles >= simd_free_at):
            inp.consumed += 1
            wgt.consumed += 1
            compute += 1
            if compute % k_beats == 0:   # tile boundary: retire 64 outputs
                retire_pending = [retire_rng.next() % B
                                  for _ in range(_BEAT_REQS)]
                # 8-lane SIMD takes 8 cycles per tile; it sits downstream
                # of the (double-buffered) accumulators, so it overlaps
                # with the next tile's compute in both modes and only
                # binds when the next tile finishes first (k_beats < 8)
                simd_free_at = cycles + 1 + max(0, _SIMD_CYCLES - k_beats)
        cycles += 1

    return SimResult(compute, cycles)


# ---------------------------------------------------------------------------
# Closed form (used by the full-workload simulator)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _e_max_load(requests: int, banks: int) -> float:
    """E[max per-bank load] of `requests` uniform requests over `banks`
    banks (Poissonized tail-sum)."""
    if requests <= 0:
        return 1.0
    lam = requests / banks
    e = 0.0
    for m in range(1, requests + 1):
        cdf = term = math.exp(-lam)
        for j in range(1, m):
            term *= lam / j
            cdf += term
        p_ge = 1.0 - cdf ** banks
        e += p_ge
        if p_ge < 1e-9:
            break
    return max(e, 1.0)


def _k_beats(op: Op, cfg: VoltraConfig) -> int:
    return max(1, math.ceil(op.K / cfg.array_k))


# Residual structural collisions between the fine-grained input walk and
# the weight super-bank group that the FIFOs cannot hide (bandwidth loss,
# not jitter). Calibrated so peak MGDP utilization matches the paper's
# 97.32% ceiling; see DESIGN.md "Temporal model calibration".
_STRUCT_COLLISION = 0.025


def _drain_limit(k_beats: int) -> float:
    """Quant-SIMD retire limit: the 8-lane SIMD drains 64 outputs in 8
    cycles, overlapped with the next tile via double-buffered accumulators
    (both modes); binds only when k_beats < 8."""
    return k_beats / max(k_beats, _SIMD_CYCLES)


def op_temporal_util(op: Op, *, cfg: VoltraConfig = VOLTRA,
                     mgdp: bool = True, strided_input: Optional[bool] = None)\
        -> float:
    """Closed-form temporal utilization (non-stalled fraction of GEMM-core
    cycles) for one op executed tile-by-tile against the shared memory."""
    B = cfg.num_banks
    k = _k_beats(op, cfg)
    strided = op.kind != "gemm" if strided_input is None else strided_input
    retire_rate = _BEAT_REQS / k

    if not mgdp:
        # synchronous: each compute cycle must land 8+8 requests (+ retire
        # amortized); stalls = E[max bank load] - 1; conv inputs strided.
        r = 2 * _BEAT_REQS + retire_rate
        base = 1.0 / _e_max_load(round(r), B)
        if strided:
            base *= 0.92        # extra intra-beat multiplicity (random banks)
        return base * _drain_limit(k)

    # MGDP steady state: offered per-bank load (super-bank is one unit on
    # its group but still occupies 8 banks)
    rho = (2 * _BEAT_REQS + retire_rate) / B
    depth = cfg.input_fifo_depth
    if rho >= 1.0:
        steady = 1.0 / rho
    else:
        p_under = (1 - rho) * rho ** depth / (1 - rho ** (depth + 1))
        steady = 1.0 - p_under
    return steady * (1.0 - _STRUCT_COLLISION) * _drain_limit(k)


def workload_temporal_util(wl: Workload, *, cfg: VoltraConfig = VOLTRA,
                           mgdp: bool = True) -> float:
    """FLOP-weighted mean temporal utilization (Fig. 6(b) methodology:
    measured within tiled layer blocks, averaged over the network)."""
    num = den = 0.0
    for op in wl.ops:
        u = op_temporal_util(op, cfg=cfg, mgdp=mgdp)
        num += op.macs * u
        den += op.macs
    return num / den if den else 0.0


def temporal_report(wl: Workload, cfg: VoltraConfig = VOLTRA) -> dict:
    u_m = workload_temporal_util(wl, cfg=cfg, mgdp=True)
    u_p = workload_temporal_util(wl, cfg=cfg, mgdp=False)
    return {"workload": wl.name, "util_mgdp": u_m, "util_plain": u_p,
            "gain": u_m / u_p if u_p else float("inf")}
