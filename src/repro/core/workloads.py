"""The paper's 8 evaluation workloads (Fig. 6), layer-by-layer.

Every network is lowered to the op vocabulary the GEMM core executes:
``gemm`` (implicit-im2col Conv2D included) and ``dwconv`` (depthwise,
mapped per-channel). Each op carries the full (M, K, N) GEMM view plus a
``repeat`` count so per-head / per-timestep / per-channel instances are
modeled without flattening the list.

Workload-definition assumptions (the paper gives model names only):
  * MobileNetV2 / ResNet50: ImageNet 224x224, batch 1.
  * ViT-B/16: 224x224 -> 197 tokens, batch 1.
  * PointNeXt-S: 1024-point cloud, 4 set-abstraction stages (the op mix is
    representative; PointNeXt has no single canonical layer table).
  * LSTM: 1 layer, hidden=input=1024, seq 64, batch 8.
  * BERT-Base: 12L d=768 h=12 ff=3072, token size 512 (paper).
  * LLaMA3.2-3B: 28L d=3072 q=24 kv=8 hd=128 ff=8192 vocab=128256;
    prefill token size 256 (paper); decode at KV length 256 with batch 8
    (an edge-serving batch; the paper's decode batch is unpublished —
    see DESIGN.md "Workload assumptions").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class Op:
    """One GEMM-core invocation: out[M,N] += in[M,K] @ w[K,N]."""
    name: str
    M: int
    K: int
    N: int
    repeat: int = 1
    kind: str = "gemm"          # gemm | dwconv
    weight_stationary_reuse: bool = True  # False: weights used once (attn)

    @property
    def flops(self) -> float:
        return 2.0 * self.M * self.K * self.N * self.repeat

    @property
    def macs(self) -> float:
        return float(self.M) * self.K * self.N * self.repeat

    def bytes_in(self) -> int:
        return self.M * self.K  # int8

    def bytes_w(self) -> int:
        return self.K * self.N

    def bytes_out(self) -> int:
        return self.M * self.N  # int8 after quantization


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    ops: Tuple[Op, ...]

    @property
    def flops(self) -> float:
        return sum(op.flops for op in self.ops)

    @property
    def macs(self) -> float:
        return sum(op.macs for op in self.ops)


def _conv(name, h, w, cin, cout, r=1, s=1, stride=1, repeat=1) -> Op:
    ho, wo = h // stride, w // stride
    return Op(name, M=ho * wo, K=r * s * cin, N=cout, repeat=repeat)


def _dw(name, h, w, c, r=3, stride=1) -> Op:
    ho, wo = h // stride, w // stride
    # depthwise: C independent (M, R*S, 1) GEMMs
    return Op(name, M=ho * wo, K=r * r, N=1, repeat=c, kind="dwconv")


# ---------------------------------------------------------------------------
# 1. MobileNetV2 (ImageNet 224, batch 1)
# ---------------------------------------------------------------------------


def mobilenet_v2() -> Workload:
    ops: List[Op] = [_conv("stem", 224, 224, 3, 32, 3, 3, 2)]
    cin, h = 32, 112
    # (expansion t, out channels c, blocks n, stride s)
    cfgs = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
            (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    for t, c, n, s in cfgs:
        for i in range(n):
            stride = s if i == 0 else 1
            mid = cin * t
            if t != 1:
                ops.append(_conv(f"ir{c}_{i}_expand", h, h, cin, mid))
            ops.append(_dw(f"ir{c}_{i}_dw", h, h, mid, 3, stride))
            h = h // stride
            ops.append(_conv(f"ir{c}_{i}_project", h, h, mid, c))
            cin = c
    ops.append(_conv("head", 7, 7, 320, 1280))
    ops.append(Op("classifier", M=1, K=1280, N=1000))
    return Workload("MobileNetV2", tuple(ops))


# ---------------------------------------------------------------------------
# 2. ResNet50 (ImageNet 224, batch 1)
# ---------------------------------------------------------------------------


def resnet50() -> Workload:
    ops: List[Op] = [_conv("stem", 224, 224, 3, 64, 7, 7, 2)]
    h = 56  # after maxpool
    cin = 64
    stages = [(64, 256, 3, 1), (128, 512, 4, 2), (256, 1024, 6, 2),
              (512, 2048, 3, 2)]
    for mid, cout, blocks, stride in stages:
        for i in range(blocks):
            st = stride if i == 0 else 1
            ops.append(_conv(f"r{cout}_{i}_a", h, h, cin, mid, 1, 1, st))
            hh = h // st
            ops.append(_conv(f"r{cout}_{i}_b", hh, hh, mid, mid, 3, 3, 1))
            ops.append(_conv(f"r{cout}_{i}_c", hh, hh, mid, cout))
            if i == 0:
                ops.append(_conv(f"r{cout}_{i}_ds", h, h, cin, cout, 1, 1, st))
            cin, h = cout, hh
    ops.append(Op("fc", M=1, K=2048, N=1000))
    return Workload("ResNet50", tuple(ops))


# ---------------------------------------------------------------------------
# Transformer helpers
# ---------------------------------------------------------------------------


def _mha_ops(pre, S, d, heads, hd, kv_heads=None, kv_len=None,
             q_rows=None) -> List[Op]:
    """Projections + per-head score/context GEMMs (KV ops are not
    weight-stationary: K/V come from activations)."""
    kv = kv_heads or heads
    T = kv_len or S
    M = q_rows if q_rows is not None else S
    ops = [
        Op(f"{pre}.q", M=M, K=d, N=heads * hd),
        Op(f"{pre}.k", M=S, K=d, N=kv * hd),
        Op(f"{pre}.v", M=S, K=d, N=kv * hd),
        Op(f"{pre}.scores", M=M * (heads // kv), K=hd, N=T, repeat=kv,
           weight_stationary_reuse=False),
        Op(f"{pre}.ctx", M=M * (heads // kv), K=T, N=hd, repeat=kv,
           weight_stationary_reuse=False),
        Op(f"{pre}.o", M=M, K=heads * hd, N=d),
    ]
    return ops


# ---------------------------------------------------------------------------
# 3. ViT-B/16 (224 -> 197 tokens, batch 1)
# ---------------------------------------------------------------------------


def vit_b() -> Workload:
    S, d, h, ff, L = 197, 768, 12, 3072, 12
    ops: List[Op] = [Op("patch_embed", M=196, K=16 * 16 * 3, N=d)]
    for i in range(L):
        ops += _mha_ops(f"l{i}", S, d, h, d // h)
        ops += [Op(f"l{i}.ff1", M=S, K=d, N=ff),
                Op(f"l{i}.ff2", M=S, K=ff, N=d)]
    ops.append(Op("head", M=1, K=d, N=1000))
    return Workload("ViT-B", tuple(ops))


# ---------------------------------------------------------------------------
# 4. PointNeXt-S (1024 points)
# ---------------------------------------------------------------------------


def pointnext() -> Workload:
    ops: List[Op] = [Op("stem", M=1024, K=3, N=32)]
    pts, c = 1024, 32
    for stage, cout in enumerate((64, 128, 256, 512)):
        pts //= 4
        # SA: grouped neighborhood MLP (K neighbors=32) then reduction
        ops.append(Op(f"sa{stage}.mlp1", M=pts * 32, K=c + 3, N=cout))
        ops.append(Op(f"sa{stage}.mlp2", M=pts * 32, K=cout, N=cout))
        # InvResMLP x1: pw -> dw-ish grouped -> pw
        ops.append(Op(f"s{stage}.pw1", M=pts, K=cout, N=cout * 4))
        ops.append(Op(f"s{stage}.pw2", M=pts, K=cout * 4, N=cout))
        c = cout
    ops.append(Op("cls.fc1", M=1, K=512, N=512))
    ops.append(Op("cls.fc2", M=1, K=512, N=256))
    ops.append(Op("cls.fc3", M=1, K=256, N=40))
    return Workload("PointNeXt", tuple(ops))


# ---------------------------------------------------------------------------
# 5. LSTM (hidden 1024, seq 64, batch 8)
# ---------------------------------------------------------------------------


def lstm(batch: int = 8, hidden: int = 1024, seq: int = 64) -> Workload:
    ops = [
        Op("x_gates", M=batch, K=hidden, N=4 * hidden, repeat=seq),
        Op("h_gates", M=batch, K=hidden, N=4 * hidden, repeat=seq),
        Op("proj", M=batch, K=hidden, N=hidden, repeat=seq),
    ]
    return Workload("LSTM", tuple(ops))


# ---------------------------------------------------------------------------
# 6. BERT-Base (token size 512, batch 1)
# ---------------------------------------------------------------------------


def bert_base(S: int = 512) -> Workload:
    d, h, ff, L = 768, 12, 3072, 12
    ops: List[Op] = []
    for i in range(L):
        ops += _mha_ops(f"l{i}", S, d, h, d // h)
        ops += [Op(f"l{i}.ff1", M=S, K=d, N=ff),
                Op(f"l{i}.ff2", M=S, K=ff, N=d)]
    return Workload("BERT-Base", tuple(ops))


# ---------------------------------------------------------------------------
# 7/8. LLaMA3.2-3B prefill / decode
# ---------------------------------------------------------------------------

_LLAMA = dict(L=28, d=3072, heads=24, kv=8, hd=128, ff=8192, vocab=128256)


def llama32_3b_prefill(S: int = 256) -> Workload:
    c = _LLAMA
    ops: List[Op] = []
    for i in range(c["L"]):
        ops += _mha_ops(f"l{i}", S, c["d"], c["heads"], c["hd"], c["kv"])
        ops += [Op(f"l{i}.gate", M=S, K=c["d"], N=c["ff"]),
                Op(f"l{i}.up", M=S, K=c["d"], N=c["ff"]),
                Op(f"l{i}.down", M=S, K=c["ff"], N=c["d"])]
    ops.append(Op("lm_head", M=1, K=c["d"], N=c["vocab"]))
    return Workload("LLaMA3.2-3B-prefill", tuple(ops))


def llama32_3b_decode(kv_len: int = 256, batch: int = 8) -> Workload:
    """One decode step at KV length `kv_len` (see module docstring for the
    batch assumption)."""
    c = _LLAMA
    B = batch
    ops: List[Op] = []
    for i in range(c["L"]):
        ops += [
            Op(f"l{i}.q", M=B, K=c["d"], N=c["heads"] * c["hd"]),
            Op(f"l{i}.k", M=B, K=c["d"], N=c["kv"] * c["hd"]),
            Op(f"l{i}.v", M=B, K=c["d"], N=c["kv"] * c["hd"]),
            # per (batch, kv-head): 3 grouped q rows attend to the cache
            Op(f"l{i}.scores", M=c["heads"] // c["kv"], K=c["hd"], N=kv_len,
               repeat=B * c["kv"], weight_stationary_reuse=False),
            Op(f"l{i}.ctx", M=c["heads"] // c["kv"], K=kv_len, N=c["hd"],
               repeat=B * c["kv"], weight_stationary_reuse=False),
            Op(f"l{i}.o", M=B, K=c["heads"] * c["hd"], N=c["d"]),
            Op(f"l{i}.gate", M=B, K=c["d"], N=c["ff"]),
            Op(f"l{i}.up", M=B, K=c["d"], N=c["ff"]),
            Op(f"l{i}.down", M=B, K=c["ff"], N=c["d"]),
        ]
    ops.append(Op("lm_head", M=B, K=c["d"], N=c["vocab"]))
    return Workload("LLaMA3.2-3B-decode", tuple(ops))


# ---------------------------------------------------------------------------
# Registry (Fig. 6 order)
# ---------------------------------------------------------------------------


def all_workloads() -> Dict[str, Workload]:
    return {
        "mobilenetv2": mobilenet_v2(),
        "resnet50": resnet50(),
        "vit_b": vit_b(),
        "pointnext": pointnext(),
        "lstm": lstm(),
        "bert_base": bert_base(),
        "llama_prefill": llama32_3b_prefill(),
        "llama_decode": llama32_3b_decode(),
    }


# BERT-Base MHA single head, token 64 — the Fig. 4 example.
def bert_mha_head(S: int = 64, d: int = 768, hd: int = 64) -> List[Op]:
    return [
        Op("q_proj", M=S, K=d, N=hd),
        Op("k_proj", M=S, K=d, N=hd),
        Op("v_proj", M=S, K=d, N=hd),
        Op("scores", M=S, K=hd, N=S, weight_stationary_reuse=False),
        Op("ctx", M=S, K=S, N=hd, weight_stationary_reuse=False),
    ]
