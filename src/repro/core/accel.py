"""Voltra accelerator configuration — the chip, as published.

Every number below is taken from the paper (Sec. II, Fig. 2/3/5, Table I):
8x8x8 MAC array (512 INT8 MACs), 32 x 64-bit shared-memory banks (128 KB),
streamer FIFO depths, channel widths, 300-800 MHz @ 0.6-1.0 V. The few
quantities the paper leaves unspecified (off-chip DMA bandwidth, SRAM/MAC
energy-per-op) are explicit, documented assumptions calibrated against the
paper's *system-level* results (Table I peak 0.82 TOPS / 1.60 TOPS/W).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class VoltraConfig:
    # --- 3D spatial array (Sec. II-A) -----------------------------------
    array_m: int = 8            # Dot-ProdU rows   (input-matrix rows)
    array_n: int = 8            # Dot-ProdU cols   (weight-matrix cols)
    array_k: int = 8            # dot-product width inside each Dot-ProdU
    # --- shared memory (Sec. II, Fig. 2) ---------------------------------
    num_banks: int = 32
    bank_width_bits: int = 64   # per-bank port width
    mem_kib: int = 128          # data memory (D); 6 KB (I) excluded
    # --- streamers (Sec. II-B, Fig. 3) -----------------------------------
    input_fifo_depth: int = 8
    weight_fifo_depth: int = 8
    psum_fifo_depth: int = 1    # output-stationary -> rare psum traffic
    output_fifo_depth: int = 1
    input_channel_bits: int = 64    # fine-grained  (Fig. 3a)
    weight_channel_bits: int = 512  # coarse-grained super-bank (Fig. 3b)
    super_bank_banks: int = 8       # 8 x 64-bit banks fused
    # --- SIMD + crossbar time-multiplexing (Sec. II-D) --------------------
    simd_lanes: int = 8         # quantization PEs (64 outputs / 8 cycles)
    simd_outputs: int = 64      # outputs produced per array retire
    # --- datapath ---------------------------------------------------------
    in_bits: int = 8            # INT8 operands
    acc_bits: int = 32          # INT32 accumulators / partial sums
    # --- clock / voltage (Fig. 5) -----------------------------------------
    freq_min_mhz: float = 300.0
    freq_max_mhz: float = 800.0
    vdd_min: float = 0.6
    vdd_max: float = 1.0
    # --- off-chip (ASSUMPTION; paper simulates DMA with an RTL model) -----
    # A 64-bit LPDDR4x-class port at core clock: 8 bytes/cycle. This puts
    # compute:DMA balance in the regime where the paper's PDMA gains
    # (1.15-2.36x) are reproduced; recorded in DESIGN.md.
    dma_bytes_per_cycle: float = 8.0
    dma_setup_cycles: int = 100   # per-transfer fixed cost (descriptor+row)

    # --- energy model (ASSUMPTION; calibrated to the paper's measured
    # power band: P(0.6V,300MHz)=171mW and P(1.0V,800MHz)=981mW on the
    # dense 96^3 GEMM, via P = P_static + P_mac + P_sram with dynamic
    # terms scaling as (V/Vref)^2 * f. See DESIGN.md "Energy calibration".
    vdd_ref: float = 0.6
    e_mac_pj: float = 0.785       # per INT8 MAC at vdd_ref (system-level)
    e_sram_pj_per_byte: float = 0.55   # shared-memory access at vdd_ref
    e_dram_pj_per_byte: float = 16.0   # off-chip access (not V-scaled)
    p_static_mw: float = 44.6     # leakage + always-on

    # ----------------------------------------------------------------- API
    @property
    def macs(self) -> int:
        return self.array_m * self.array_n * self.array_k

    @property
    def peak_ops_per_cycle(self) -> int:
        return 2 * self.macs                      # MAC = 2 ops

    def peak_tops(self, freq_mhz: float | None = None) -> float:
        f = self.freq_max_mhz if freq_mhz is None else freq_mhz
        return self.peak_ops_per_cycle * f * 1e6 / 1e12

    @property
    def mem_bytes(self) -> int:
        return self.mem_kib * 1024

    @property
    def bank_bytes(self) -> int:
        return self.mem_bytes // self.num_banks

    @property
    def bank_width_bytes(self) -> int:
        return self.bank_width_bits // 8

    @property
    def input_channel_bytes(self) -> int:
        return self.input_channel_bits // 8

    @property
    def weight_channel_bytes(self) -> int:
        return self.weight_channel_bits // 8

    def freq_at(self, vdd: float) -> float:
        """Linear frequency/voltage interpolation over the shmoo band."""
        t = (vdd - self.vdd_min) / (self.vdd_max - self.vdd_min)
        return self.freq_min_mhz + t * (self.freq_max_mhz - self.freq_min_mhz)

    # Per-cycle operand demand of the fully-active GEMM core (bytes).
    @property
    def input_demand(self) -> int:
        return self.array_m * self.array_k * self.in_bits // 8   # 64 B

    @property
    def weight_demand(self) -> int:
        return self.array_n * self.array_k * self.in_bits // 8   # 64 B

    @property
    def output_tile_bytes(self) -> int:
        return self.array_m * self.array_n * self.acc_bits // 8  # 256 B


# The chip as fabricated.
VOLTRA = VoltraConfig()


@dataclasses.dataclass(frozen=True)
class Baseline2DConfig:
    """The conventional 2D comparison point of Fig. 6(a): the same 512 MACs
    arranged as an output-stationary M x N grid with K fully temporal."""
    array_m: int = 16
    array_n: int = 32

    @property
    def macs(self) -> int:
        return self.array_m * self.array_n


BASELINE_2D = Baseline2DConfig()


@dataclasses.dataclass(frozen=True)
class SeparatedMemConfig:
    """Separated-buffer baseline of Fig. 1(a)/6(c): same total SRAM split
    into fixed per-operand buffers with dedicated dispatchers."""
    input_kib: int = 64
    weight_kib: int = 32
    output_kib: int = 32

    @property
    def total_kib(self) -> int:
        return self.input_kib + self.weight_kib + self.output_kib

    def budget(self, operand: str) -> int:
        return {"input": self.input_kib, "weight": self.weight_kib,
                "output": self.output_kib}[operand] * 1024


SEPARATED_MEM = SeparatedMemConfig()
