"""Streamer AGU programming model — Sec. II-B's 6-D affine address
generation, as the Snitch core programs it through CSRs.

An ``AGUDescriptor`` is exactly the paper's streamer configuration: a
base pointer plus up to 6 (bound, stride) loop pairs; the generated
address stream is

    addr(i0..i5) = base + sum_d i_d * stride_d,   0 <= i_d < bound_d

with the innermost loop last. Two generators build the descriptors the
chip needs:

  * ``im2col_descriptor`` — the input streamer's implicit-im2col walk for
    any Conv2D (arbitrary stride / kernel / channels), in either HWC or
    the reshuffler's C/8HWC8 blocked layout;
  * ``gemm_descriptors`` — the block-wise input/weight walks of a tiled
    output-stationary GEMM.

``addresses()`` interprets a descriptor into its concrete stream (the
oracle-validated contract: tests compare against an explicit-im2col
gather), and ``bank_conflict_profile()`` replays a stream against the
32-bank map to quantify the reshuffler's purpose: HWC walks collide
inside a beat, C/8HWC8 walks do not (Sec. II-E, validated in
tests/test_agu.py).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Sequence, Tuple

from repro.core.accel import VOLTRA, VoltraConfig

MAX_DIMS = 6


@dataclasses.dataclass(frozen=True)
class AGUDescriptor:
    """base + up to 6 nested affine loops (outermost first)."""
    base: int
    bounds: Tuple[int, ...]
    strides: Tuple[int, ...]          # bytes
    elem_bytes: int = 8               # one 64-bit beat element

    def __post_init__(self):
        assert len(self.bounds) == len(self.strides)
        assert 1 <= len(self.bounds) <= MAX_DIMS, "AGU supports up to 6-D"
        assert all(b > 0 for b in self.bounds)

    @property
    def count(self) -> int:
        n = 1
        for b in self.bounds:
            n *= b
        return n


def addresses(desc: AGUDescriptor) -> List[int]:
    """Interpret the descriptor into its address stream (the RTL's
    behaviour, used as the contract in tests)."""
    out = []
    for idx in itertools.product(*(range(b) for b in desc.bounds)):
        out.append(desc.base
                   + sum(i * s for i, s in zip(idx, desc.strides)))
    return out


# ---------------------------------------------------------------------------
# Conv2D: implicit im2col input walk
# ---------------------------------------------------------------------------


def im2col_descriptor(*, H: int, W: int, C: int, R: int, S: int,
                      stride: int = 1, base: int = 0,
                      layout: str = "HWC") -> AGUDescriptor:
    """Input-streamer program for implicit-im2col Conv2D (valid padding;
    the DMA handles halo padding).

    The GEMM core consumes one beat = 8 x 64-bit words per cycle, one per
    array ROW — i.e. the same (kh, kw, c-block) tap for 8 *adjacent
    output pixels* (the M dimension of the implicit GEMM). The innermost
    AGU loop therefore walks 8 output pixels, and the full nest is
    exactly 6-D: (oh, ow-block, kh, kw, c-block, ow-in-block) — this is
    why the chip's input streamer needs a 6-D AGU.

    HWC:     word addr stride between adjacent pixels = stride*C bytes —
             aliases the 32-bank map whenever stride*C % 256 == 0
             (any C >= 256... exactly what the reshuffler exists to fix).
    C/8HWC8: blocked (C/8, H, W, 8): adjacent pixels are adjacent words
             (8 bytes apart) — conflict-free beats by construction.
    """
    OH = (H - R) // stride + 1
    OW = (W - S) // stride + 1
    assert OW % 8 == 0, "beat grouping needs OW % 8 == 0 (pad W)"
    cb = max(C // 8, 1)
    if layout == "HWC":
        return AGUDescriptor(
            base=base,
            bounds=(OH, OW // 8, R, S, cb, 8),
            strides=(stride * W * C, 8 * stride * C, W * C, C, 8,
                     stride * C),
            elem_bytes=8)
    if layout == "C8HWC8":
        return AGUDescriptor(
            base=base,
            bounds=(OH, OW // 8, R, S, cb, 8),
            strides=(stride * W * 8, 8 * stride * 8, W * 8, 8, H * W * 8,
                     stride * 8),
            elem_bytes=8)
    raise ValueError(layout)


def im2col_reference(*, H: int, W: int, C: int, R: int, S: int,
                     stride: int = 1, layout: str = "HWC") -> List[int]:
    """Oracle: explicit im2col gather addresses (word granularity), in
    the beat order the array consumes (8 adjacent output pixels/beat)."""
    OH = (H - R) // stride + 1
    OW = (W - S) // stride + 1
    out = []
    cb = max(C // 8, 1)
    for oh in range(OH):
        for owb in range(OW // 8):
            for kh in range(R):
                for kw in range(S):
                    for c in range(cb):
                        for oi in range(8):
                            ow = owb * 8 + oi
                            ih = oh * stride + kh
                            iw = ow * stride + kw
                            if layout == "HWC":
                                out.append((ih * W + iw) * C + 8 * c)
                            else:
                                out.append(c * H * W * 8
                                           + (ih * W + iw) * 8)
    return out


# ---------------------------------------------------------------------------
# GEMM: block-wise walks (3-D AGU weight streamer / 6-D input streamer)
# ---------------------------------------------------------------------------


def gemm_descriptors(M: int, K: int, N: int, *, tm: int = 8, tn: int = 8,
                     in_base: int = 0, w_base: int = 0
                     ) -> Dict[str, AGUDescriptor]:
    """Input + weight streamer programs for an output-stationary tiled
    GEMM (row-major int8 operands; one K-row of a tile per beat)."""
    assert M % tm == 0 and N % tn == 0 and K % 8 == 0
    kb = K // 8
    return {
        # loops: n-tile, m-tile, m-in-tile, k-beat
        "input": AGUDescriptor(
            base=in_base,
            bounds=(N // tn, M // tm, tm, kb),
            strides=(0, tm * K, K, 8),
            elem_bytes=8),
        # loops: n-tile, m-tile(rewind), n-in-tile, k-beat  (3-D pattern
        # + rewind dim; weights are pre-laid-out K-major per column)
        "weight": AGUDescriptor(
            base=w_base,
            bounds=(N // tn, M // tm, tn, kb),
            strides=(tn * K, 0, K, 8),
            elem_bytes=8),
    }


# ---------------------------------------------------------------------------
# Bank-conflict profile: what the reshuffler buys (Sec. II-E)
# ---------------------------------------------------------------------------


def bank_conflict_profile(stream: Sequence[int], *,
                          cfg: VoltraConfig = VOLTRA,
                          beat_words: int = 8) -> Dict[str, float]:
    """Replay a word-address stream in beats of `beat_words` requests and
    measure intra-beat bank conflicts on the word-interleaved 32-bank map
    (bank = (addr/8) % 32). Returns conflict statistics; a conflict-free
    layout sustains 1 beat/cycle, multiplicity m needs m cycles."""
    B = cfg.num_banks
    beats = 0
    cycles = 0
    worst = 0
    for i in range(0, len(stream) - beat_words + 1, beat_words):
        banks = [(a // 8) % B for a in stream[i:i + beat_words]]
        mult = max(banks.count(b) for b in set(banks))
        beats += 1
        cycles += mult
        worst = max(worst, mult)
    return {
        "beats": float(beats),
        "cycles": float(cycles),
        "throughput": beats / cycles if cycles else 0.0,
        "worst_multiplicity": float(worst),
    }
