"""repro.core — the paper's contribution as an architectural model.

  accel      — VoltraConfig (the chip's published parameters) + baselines
  workloads  — the 8 Fig. 6 evaluation networks, layer-by-layer
  spatial    — C1: 3D vs 2D spatial utilization (Fig. 6a)
  temporal   — C2: bank contention + MGDP, event sim + closed form (Fig. 6b)
  tiling     — C3: output-stationary tiling, shared vs separated arenas
  pdma       — C3: arena allocator + MHA residency/access counts (Fig. 4, 1c)
  simulator  — end-to-end latency/energy (Fig. 6c, Fig. 7, Table I)
  agu        — Sec. II-B: 6-D affine streamer descriptors (implicit
               im2col for any Conv2D), address-stream interpreter and
               bank-conflict profiling (the reshuffler claim, quantified)
"""
from repro.core.accel import (BASELINE_2D, SEPARATED_MEM, VOLTRA,
                              Baseline2DConfig, SeparatedMemConfig,
                              VoltraConfig)
from repro.core.workloads import Op, Workload, all_workloads

__all__ = [
    "BASELINE_2D", "SEPARATED_MEM", "VOLTRA", "Baseline2DConfig",
    "SeparatedMemConfig", "VoltraConfig", "Op", "Workload", "all_workloads",
]
