"""Output-stationary tiling planner: shared (PDMA) vs separated arenas.

For each GEMM-core op the planner picks an (Tm, Tk, Tn) tile so the
working set fits on-chip and off-chip (DMA) traffic is minimized:

  loop ni:  loop mi:  loop ki:            # output-stationary: ki innermost
      in_tile  (Tm x Tk)  — loaded ceil(N/Tn) times over the whole op
      w_tile   (Tk x Tn)  — loaded ceil(M/Tm) times
      out_tile (Tm x Tn)  — written once (int8 after the quant SIMD);
                            if Tk < K the int32 partial sums spill to
                            memory between K-chunks (read+write each pass)

Arena models:
  * shared (PDMA, Sec. II-C): one constraint — the double-buffered stream
    tiles plus the output/psum tile must fit the single 128 KB memory.
    The planner re-partitions it per layer (this is exactly the paper's
    "programmable dynamic memory allocation").
  * separated (Fig. 1(a) baseline): three constraints — each operand's
    tile must fit its fixed dedicated buffer (64/32/32 KB), regardless of
    how empty the other buffers are. This is what inflates DMA traffic:
    the tiling must conform to the smallest relevant buffer.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import List, Optional, Tuple

from repro.core.accel import (SEPARATED_MEM, VOLTRA, SeparatedMemConfig,
                              VoltraConfig)
from repro.core.workloads import Op, Workload


@dataclasses.dataclass(frozen=True)
class TilePlan:
    tm: int
    tk: int
    tn: int
    dma_in: int          # bytes
    dma_w: int
    dma_out: int
    dma_psum: int
    footprint: int       # on-chip bytes actually used (shared view)

    @property
    def dma_total(self) -> int:
        return self.dma_in + self.dma_w + self.dma_out + self.dma_psum

    @property
    def k_split(self) -> int:
        return 0 if self.dma_psum == 0 else 1


def _r8(x: int) -> int:
    return max(8, 8 * math.ceil(x / 8))


def _cands(dim: int, cap: int = 4096) -> List[int]:
    """Candidate tile sizes for one dimension: 8*2^i ladder + exact."""
    d8 = _r8(dim)
    out = {min(d8, 8 * (1 << i)) for i in range(12) if 8 * (1 << i) <= 2 * d8}
    out.add(d8)
    return sorted(x for x in out if x <= max(cap, d8))


def _plan(op_m: int, op_k: int, op_n: int, arena: str,
          cfg: VoltraConfig, sep: SeparatedMemConfig,
          acc_bytes: int) -> TilePlan:
    M, K, N = _r8(op_m), _r8(op_k), _r8(op_n)
    best: Optional[TilePlan] = None
    shared_budget = cfg.mem_bytes
    for tk in _cands(K):
        for tm in _cands(M):
            for tn in _cands(N):
                nK = math.ceil(K / tk)
                spill = nK > 1
                out_b = tm * tn * (acc_bytes if spill else 1)
                in_t, w_t = tm * tk, tk * tn
                if arena == "shared":
                    if 2 * (in_t + w_t) + out_b > shared_budget:
                        continue
                else:
                    if (2 * in_t > sep.budget("input")
                            or 2 * w_t > sep.budget("weight")
                            or out_b > sep.budget("output")):
                        continue
                nM, nN = math.ceil(M / tm), math.ceil(N / tn)
                if nK == 1:
                    # full-K tiles: the outer-loop operand strip stays
                    # resident, so one of the two reload factors drops
                    # (loop-order freedom: mi-outer keeps input strips,
                    # ni-outer keeps weight strips)
                    dma_in, dma_w = min(
                        (M * K, K * N * nM),          # mi outermost
                        (M * K * nN, K * N),          # ni outermost
                        key=sum)
                else:
                    dma_in, dma_w = M * K * nN, K * N * nM
                dma_out = M * N
                dma_ps = 2 * M * N * acc_bytes * (nK - 1)
                plan = TilePlan(tm, tk, tn, dma_in, dma_w, dma_out, dma_ps,
                                2 * (in_t + w_t) + out_b)
                key = (plan.dma_total, -tk, -(tm * tn))
                if best is None or key < (best.dma_total, -best.tk,
                                          -(best.tm * best.tn)):
                    best = plan
    assert best is not None, "no feasible tiling (op too large for arena?)"
    return best


@lru_cache(maxsize=100_000)
def _plan_cached(m: int, k: int, n: int, arena: str,
                 mem_kib: int, in_kib: int, w_kib: int, out_kib: int,
                 acc: int) -> TilePlan:
    cfg = dataclasses.replace(VOLTRA, mem_kib=mem_kib)
    sep = SeparatedMemConfig(in_kib, w_kib, out_kib)
    return _plan(m, k, n, arena, cfg, sep, acc)


def plan_op(op: Op, arena: str = "shared", *, cfg: VoltraConfig = VOLTRA,
            sep: SeparatedMemConfig = SEPARATED_MEM) -> TilePlan:
    """Best tiling of `op` for the given arena ("shared" | "separated")."""
    return _plan_cached(op.M, op.K, op.N, arena, cfg.mem_kib,
                        sep.input_kib, sep.weight_kib, sep.output_kib,
                        cfg.acc_bits // 8)


def plan_op_naive_separated(op: Op, *, cfg: VoltraConfig = VOLTRA,
                            sep: SeparatedMemConfig = SEPARATED_MEM
                            ) -> TilePlan:
    """The paper's separated baseline: start from the shared-optimal tile
    shape and shrink dimensions until every operand fits its fixed buffer
    ("the tiling strategy must conform to the size of the smallest
    buffer") — no joint re-optimization across buffers, and fixed
    dispatchers reload both streamed operands (no loop-order tricks
    beyond full residency in a dedicated buffer)."""
    base = plan_op(op, "shared", cfg=cfg, sep=sep)
    tm, tk, tn = base.tm, base.tk, base.tn
    M, K, N = _r8(op.M), _r8(op.K), _r8(op.N)
    acc = cfg.acc_bits // 8

    def fits(tm, tk, tn):
        spill = tk < K
        return (2 * tm * tk <= sep.budget("input")
                and 2 * tk * tn <= sep.budget("weight")
                and tm * tn * (acc if spill else 1) <= sep.budget("output"))

    guard = 0
    while not fits(tm, tk, tn) and guard < 64:
        guard += 1
        # shrink the dimension of the most-overfull operand
        ratios = {
            "in": 2 * tm * tk / sep.budget("input"),
            "w": 2 * tk * tn / sep.budget("weight"),
            "out": tm * tn * (acc if tk < K else 1) / sep.budget("output"),
        }
        worst = max(ratios, key=ratios.get)
        if worst == "in":
            if tm > 8:
                tm = _r8(tm // 2)
            else:
                tk = _r8(tk // 2)
        elif worst == "w":
            if tn > 8:
                tn = _r8(tn // 2)
            else:
                tk = _r8(tk // 2)
        else:
            if tm >= tn and tm > 8:
                tm = _r8(tm // 2)
            else:
                tn = _r8(tn // 2)
    nM, nK, nN = math.ceil(M / tm), math.ceil(K / tk), math.ceil(N / tn)
    in_res = tm >= M and tk >= K        # whole input in its buffer
    w_res = tk >= K and tn >= N
    dma_in = M * K * (1 if in_res else nN)
    dma_w = K * N * (1 if w_res else nM)
    dma_out = M * N
    dma_ps = 2 * M * N * acc * (nK - 1)
    return TilePlan(tm, tk, tn, dma_in, dma_w, dma_out, dma_ps,
                    2 * (tm * tk + tk * tn)
                    + tm * tn * (acc if nK > 1 else 1))


def workload_dma_bytes(wl: Workload, arena: str = "shared",
                       cfg: VoltraConfig = VOLTRA) -> int:
    if arena == "naive_separated":
        return sum(plan_op_naive_separated(op, cfg=cfg).dma_total
                   * op.repeat for op in wl.ops)
    return sum(plan_op(op, arena, cfg=cfg).dma_total * op.repeat
               for op in wl.ops)


def tile_operand_bytes(plan: TilePlan, acc_bytes: int = 4
                       ) -> Tuple[int, int, int]:
    """(input, weight, output) on-chip bytes of one tile set (streamed
    operands double-buffered)."""
    out = plan.tm * plan.tn * (acc_bytes if plan.k_split else 1)
    return 2 * plan.tm * plan.tk, 2 * plan.tk * plan.tn, out


def memory_usage_report(wl: Workload, *, cfg: VoltraConfig = VOLTRA) -> dict:
    """Fig. 1(c): memory that must be PROVISIONED for the same tiling.

    Pick one tiling per layer (the shared planner's). A separated design
    must provision each dedicated buffer for its worst layer —
    sum_operand(max_layer(bytes)) — while the shared memory provisions
    only max_layer(sum_operand(bytes)): input-heavy and weight-heavy
    layers time-share the same banks. The paper reports ~50% saving for
    ResNet50.
    """
    per_layer = []
    for op in wl.ops:
        p = plan_op(op, "shared", cfg=cfg)
        per_layer.append(tile_operand_bytes(p, cfg.acc_bits // 8))
    shared_need = max(sum(t) for t in per_layer)
    sep_need = sum(max(t[i] for t in per_layer) for i in range(3))
    return {
        "workload": wl.name,
        "shared_provisioned_bytes": shared_need,
        "separated_provisioned_bytes": sep_need,
        "saving_frac": 1.0 - shared_need / sep_need,
    }
