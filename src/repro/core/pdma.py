"""Programmable dynamic memory allocation (PDMA) — Sec. II-C, Fig. 4.

Two pieces:

1. ``Arena`` — a first-fit allocator over the shared 32-bank memory with
   bank-granular placement, modeling the streamers' programmable base
   pointers. The MHA chain planner uses it to keep intermediates resident.

2. ``mha_access_counts`` — the Fig. 4 experiment: run the BERT-Base MHA
   computation sequence (Q = X Wq, K = X Wk, V = X Wv, S = Q K^T,
   A = softmax(S), O = A V) through (a) the shared memory with dynamic
   base-pointer updates + the weight streamer's on-the-fly K^T transposer,
   and (b) a separated-buffer architecture with fixed dispatchers, where
   every intermediate must round-trip through off-chip memory to reach the
   next op's input/weight port, and K^T needs a dedicated transposer pass.
   The reported metric is total data access count (SRAM + DRAM accesses),
   matching Fig. 4(c)'s "saved memory access count".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.accel import VOLTRA, VoltraConfig

# ---------------------------------------------------------------------------
# Arena allocator (bank-granular, first-fit, programmable base pointers)
# ---------------------------------------------------------------------------


class ArenaError(Exception):
    pass


@dataclasses.dataclass
class Block:
    name: str
    offset: int
    size: int


class Arena:
    """First-fit allocator over the shared memory (byte addresses, aligned
    to the 64-bit bank word). free() makes space reusable — this is the
    "dynamic (re)partitioning" the streamers' base pointers enable."""

    def __init__(self, cfg: VoltraConfig = VOLTRA):
        self.cfg = cfg
        self.capacity = cfg.mem_bytes
        self.align = cfg.bank_width_bytes
        self.blocks: List[Block] = []

    def _aligned(self, x: int) -> int:
        return -(-x // self.align) * self.align

    def alloc(self, name: str, size: int) -> Block:
        size = self._aligned(size)
        taken = sorted((b.offset, b.offset + b.size) for b in self.blocks)
        prev = 0
        for off, end in taken + [(self.capacity, self.capacity)]:
            if off - prev >= size:
                blk = Block(name, prev, size)
                self.blocks.append(blk)
                return blk
            prev = max(prev, end)
        raise ArenaError(
            f"arena full: cannot place {name} ({size} B) in "
            f"{self.capacity} B with {self.used} B used")

    def free(self, name: str) -> None:
        keep = [b for b in self.blocks if b.name != name]
        if len(keep) == len(self.blocks):
            raise ArenaError(f"free of unknown block {name}")
        self.blocks = keep

    @property
    def used(self) -> int:
        return sum(b.size for b in self.blocks)

    @property
    def peak_ok(self) -> bool:
        return self.used <= self.capacity

    def overlaps(self) -> bool:
        iv = sorted((b.offset, b.offset + b.size) for b in self.blocks)
        return any(a[1] > b[0] for a, b in zip(iv, iv[1:]))


# ---------------------------------------------------------------------------
# Fig. 4: MHA chain residency + access counting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AccessCount:
    sram: int = 0
    dram: int = 0

    @property
    def total(self) -> int:
        return self.sram + self.dram


def _gemm_accesses(M: int, K: int, N: int, acc: AccessCount,
                   out_bytes: int = 1) -> None:
    """One GEMM pass through the core: read both operands, write output."""
    acc.sram += M * K + K * N + M * N * out_bytes


def mha_access_counts(S: int = 64, d: int = 768, hd: int = 64,
                      cfg: VoltraConfig = VOLTRA) -> Dict[str, object]:
    """Fig. 4(b)/(c): one BERT-Base head, token size 64.

    Returns access counts for the shared (PDMA) and separated designs and
    the peak arena footprint of the PDMA schedule.
    """
    bx = S * d          # X        (int8)
    bw = d * hd         # Wq/Wk/Wv (int8)
    bq = S * hd         # Q/K/V/O  (int8)
    bs = S * S          # S/A      (int8 after SIMD requant)

    # ---------------- shared / PDMA schedule (Fig. 4(b)) -----------------
    shared = AccessCount()
    arena = Arena(cfg)
    peak = 0
    # X arrives once from off-chip and stays resident for all 3 projections
    arena.alloc("X", bx)
    shared.dram += bx
    shared.sram += bx                      # write once into shared memory
    for w in ("Wq", "Wk", "Wv"):
        arena.alloc(w, bw)
        shared.dram += bw                  # stream weights from off-chip
        shared.sram += bw                  # into shared memory
        peak = max(peak, arena.used)
        _gemm_accesses(S, d, hd, shared)   # read X, read W, write Q/K/V
        arena.alloc({"Wq": "Q", "Wk": "K", "Wv": "V"}[w], bq)
        arena.free(w)                      # weight space reused (PDMA)
    arena.free("X")
    peak = max(peak, arena.used)
    # S = Q K^T : K^T happens on the fly in the weight streamer — no
    # separate transpose pass, K is just read through the transposer
    _gemm_accesses(S, hd, S, shared)
    arena.alloc("S", bs)
    arena.free("Q")
    peak = max(peak, arena.used)
    # softmax on the SIMD unit: read S, write A (in place footprint-wise)
    shared.sram += 2 * bs
    # O = A V
    _gemm_accesses(S, S, hd, shared)
    arena.alloc("O", bq)
    arena.free("S")
    arena.free("K")
    peak = max(peak, arena.used)
    # O leaves to off-chip (next layer's separate schedule)
    shared.sram += bq
    shared.dram += bq

    # ---------------- separated-buffer baseline --------------------------
    # Fixed input/weight/output buffers with fixed dispatchers: every
    # producer->consumer hop crosses off-chip memory (output buffer cannot
    # feed the input/weight ports), and K^T needs a dedicated transposer
    # pass (read K, write K^T).
    sep = AccessCount()
    sep.dram += bx                         # X into the input buffer (held
    sep.sram += bx                         # across the three projections)
    for _ in ("Wq", "Wk", "Wv"):
        sep.dram += bw
        sep.sram += bw
        _gemm_accesses(S, d, hd, sep)
        sep.sram += bq                     # drain output buffer
        sep.dram += bq                     # spill Q/K/V off-chip
    # dedicated transposer pass for K^T
    sep.dram += bq
    sep.sram += bq
    sep.sram += bq
    sep.dram += bq
    # S = Q K^T: reload Q (input) and K^T (weight)
    for b in (bq, bq):
        sep.dram += b
        sep.sram += b
    _gemm_accesses(S, hd, S, sep)
    sep.sram += bs
    sep.dram += bs                         # spill S
    # softmax: reload S, write A, spill A
    sep.dram += bs
    sep.sram += 2 * bs + bs
    sep.dram += bs
    # O = A V: reload A and V
    for b in (bs, bq):
        sep.dram += b
        sep.sram += b
    _gemm_accesses(S, S, hd, sep)
    sep.sram += bq
    sep.dram += bq

    # Sensitivity: if the separated input dispatcher cannot retain X
    # across the three projections, X is re-fetched twice more.
    sep_refetch = AccessCount(sep.sram + 2 * bx, sep.dram + 2 * bx)

    return {
        "shared": shared,
        "separated": sep,
        "separated_refetch": sep_refetch,
        "saving_frac": 1.0 - shared.total / sep.total,
        "saving_frac_refetch": 1.0 - shared.total / sep_refetch.total,
        "peak_arena_bytes": peak,
        "arena_capacity": cfg.mem_bytes,
    }
