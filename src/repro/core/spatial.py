"""3D vs 2D spatial-utilization model — Fig. 6(a).

Spatial utilization of an output-stationary array on a GEMM (M, K, N) is
the time-averaged fraction of MACs holding useful work:

    util = M*K*N / (ceil(M/um)*um * ceil(K/uk)*uk * ceil(N/un)*un)

i.e. the product of per-dimension tile-edge efficiencies. The 3D array
unrolls (um, un, uk) = (8, 8, 8); the conventional 2D baseline unrolls the
same 512 MACs as (16, 32) over (M, N) with K fully temporal (uk = 1, which
never wastes). The 3D advantage comes from needing only 8-divisibility in
M and N instead of 16/32-divisibility; its cost is K-edge waste when
K % 8 != 0 (e.g. ResNet stem K=27) — both effects are modeled.

Mapper modes (see DESIGN.md "Spatial mapper"):
  * strict    — fixed binding M->rows, N->cols, K->dot-product. This is the
                mode that reproduces the paper's "up to 2.0x vs 2D" headline
                (a GEMV saturates at 1/8 vs 1/16 of the respective arrays).
  * flexible  — additionally allows OpenGeMM-style spatial accumulation
                (rows extend K when M==1) and N-folding across rows; an
                upper bound on what a smarter mapper could reach.
"""
from __future__ import annotations

import math

from repro.core.accel import BASELINE_2D, VOLTRA, Baseline2DConfig, VoltraConfig
from repro.core.workloads import Op, Workload


def _eff(dim: int, unroll: int) -> float:
    """Tile-edge efficiency of one dimension: dim / (unroll*ceil(dim/unroll))."""
    if dim <= 0:
        return 0.0
    return dim / (unroll * math.ceil(dim / unroll))


def op_spatial_util_3d(op: Op, cfg: VoltraConfig = VOLTRA,
                       mode: str = "strict") -> float:
    um, un, uk = cfg.array_m, cfg.array_n, cfg.array_k
    strict = _eff(op.M, um) * _eff(op.N, un) * _eff(op.K, uk)
    if mode == "strict":
        return strict
    cands = [strict]
    if op.M < um:
        # spatial accumulation: rows extend the K reduction (um*uk wide),
        # M runs temporally (no spatial waste in M)
        cands.append(_eff(op.K, um * uk) * _eff(op.N, un))
        # N-folding: rows carry extra output columns, M temporal
        cands.append(_eff(op.N, um * un) * _eff(op.K, uk))
    return max(min(c, 1.0) for c in cands)


def op_spatial_util_2d(op: Op, cfg: Baseline2DConfig = BASELINE_2D) -> float:
    return _eff(op.M, cfg.array_m) * _eff(op.N, cfg.array_n)


def workload_spatial_util(wl: Workload, *, array: str = "3d",
                          mode: str = "strict",
                          weighting: str = "arithmetic") -> float:
    """Workload-level spatial utilization over the op list.

    weighting="arithmetic": FLOP-weighted mean of per-op utilization — the
    per-tiled-layer-block average Fig. 6(a) reports (each layer's
    utilization measured in isolation, then averaged over the network).
    weighting="harmonic": cycle-weighted (total useful MACs / total MAC
    slots over the whole run) — the stricter whole-run occupancy; low-util
    ops inflate their cycle share here.
    """
    if weighting == "arithmetic":
        num = den = 0.0
        for op in wl.ops:
            u = (op_spatial_util_3d(op, mode=mode) if array == "3d"
                 else op_spatial_util_2d(op))
            num += op.macs * u
            den += op.macs
        return num / den if den else 0.0
    num = den = 0.0
    for op in wl.ops:
        u = (op_spatial_util_3d(op, mode=mode) if array == "3d"
             else op_spatial_util_2d(op))
        num += op.macs
        den += op.macs / max(u, 1e-12)
    return num / den if den else 0.0


def spatial_cycles(op: Op, cfg: VoltraConfig = VOLTRA) -> int:
    """Ideal (stall-free) GEMM-core cycles for an op on the 3D array."""
    um, un, uk = cfg.array_m, cfg.array_n, cfg.array_k
    tiles = (math.ceil(op.M / um) * math.ceil(op.N / un)
             * math.ceil(op.K / uk))
    return tiles * op.repeat


def workload_cycles(wl: Workload, cfg: VoltraConfig = VOLTRA) -> int:
    return sum(spatial_cycles(op, cfg) for op in wl.ops)


def spatial_report(wl: Workload) -> dict:
    u3 = workload_spatial_util(wl, array="3d")
    u2 = workload_spatial_util(wl, array="2d")
    return {"workload": wl.name, "util_3d": u3, "util_2d": u2,
            "gain": u3 / u2 if u2 else float("inf"),
            "util_3d_cycle": workload_spatial_util(wl, array="3d",
                                                   weighting="harmonic"),
            "util_2d_cycle": workload_spatial_util(wl, array="2d",
                                                   weighting="harmonic")}
