"""JAX version-compat shims (feature-detected, no version-string parsing).

Supported range: JAX 0.4.37 – 0.6.x. Policy (see ROADMAP.md "Open items"):
every API that was renamed/added across that range is resolved HERE, once,
by feature detection — call sites import from ``repro.compat`` and never
touch ``hasattr`` themselves. Shims are detected at import time so a
missing symbol fails loudly and early, not mid-kernel.

Current shims:

* ``tpu_compiler_params`` — ``pltpu.TPUCompilerParams`` (<= 0.4.x) was
  renamed ``pltpu.CompilerParams`` (>= 0.5). Both take the same
  ``dimension_semantics=...`` kwargs we use.
* ``make_mesh`` — ``jax.make_mesh`` grew an ``axis_types=`` kwarg (and
  ``jax.sharding.AxisType``) in 0.5. On older JAX every axis is already
  implicitly Auto, so dropping the kwarg is semantics-preserving.
* ``shard_map`` — promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map`` in 0.6, and its replication-check kwarg was renamed
  ``check_rep`` -> ``check_vma`` along the way. We always DISABLE the
  check: the manual bodies the serving path maps contain ``pallas_call``
  and explicit ``psum``s, which the checker cannot type.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.experimental.pallas import tpu as pltpu

# --------------------------------------------------------------------------
# Pallas TPU compiler params: CompilerParams (new) vs TPUCompilerParams (old)
# --------------------------------------------------------------------------

if hasattr(pltpu, "CompilerParams"):
    _COMPILER_PARAMS_CLS = pltpu.CompilerParams
else:
    _COMPILER_PARAMS_CLS = pltpu.TPUCompilerParams


def tpu_compiler_params(
        *, dimension_semantics: Optional[Tuple[str, ...]] = None,
        **kwargs: Any):
    """Version-portable ``compiler_params=`` value for ``pl.pallas_call``."""
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = dimension_semantics
    return _COMPILER_PARAMS_CLS(**kwargs)


# --------------------------------------------------------------------------
# Mesh construction: axis_types= only exists on JAX >= 0.5
# --------------------------------------------------------------------------

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing `mesh` as the ambient mesh.

    ``jax.set_mesh`` appeared in 0.5; on 0.4.x ``Mesh`` itself is the
    context manager with the same enter/exit semantics.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# jax.make_mesh has taken devices= across the whole supported range, but
# feature-detect per the shim policy so a future rename fails HERE.
_MAKE_MESH_HAS_DEVICES = \
    "devices" in inspect.signature(jax.make_mesh).parameters


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence[Any]] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with every axis explicitly Auto where the concept
    exists (JAX >= 0.5) and implicitly Auto where it doesn't (0.4.x).
    ``devices``: explicit device list (e.g. a replica's slice of
    ``jax.devices()``); default lets JAX pick all local devices."""
    kwargs: dict = {}
    if devices is not None:
        if not _MAKE_MESH_HAS_DEVICES:
            import numpy as np
            return jax.sharding.Mesh(
                np.asarray(devices).reshape(tuple(axis_shapes)),
                tuple(axis_names))
        kwargs["devices"] = tuple(devices)
    if HAS_AXIS_TYPE:
        kwargs["axis_types"] = \
            (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


# --------------------------------------------------------------------------
# shard_map: jax.shard_map (>= 0.6) vs jax.experimental.shard_map (0.4/0.5),
# check_rep (old) vs check_vma (new) — always off, see module docstring
# --------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _SHARD_MAP = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _SHARD_MAP

_SHARD_MAP_CHECK_KWARG = next(
    (k for k in ("check_rep", "check_vma")
     if k in inspect.signature(_SHARD_MAP).parameters), None)


def shard_map(fn, mesh: jax.sharding.Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with the replication check disabled
    (manual bodies here carry pallas_call + explicit psums, which the
    checker rejects)."""
    kwargs = {_SHARD_MAP_CHECK_KWARG: False} if _SHARD_MAP_CHECK_KWARG \
        else {}
    return _SHARD_MAP(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


# --------------------------------------------------------------------------
# Canary self-test: re-run every shim's feature detection, report per shim
# --------------------------------------------------------------------------

def selftest() -> dict:
    """Re-resolve every shim and report how each one landed.

    The weekly ``compat-canary`` CI job runs this against JAX prereleases
    (``jax>=0.7.0.dev0 --pre``) and posts the output in its step summary:
    when upstream renames an API again, the summary names the SHIM that
    needs a new branch, instead of leaving a mid-suite AttributeError to
    bisect. Every value is ``"OK: <how it resolved>"`` or
    ``"FAIL: <exception>"``; a FAIL here is always a missing detection
    branch in this module, never a caller bug."""
    checks = {
        # construct the params object for real — the rename history is
        # TPUCompilerParams -> CompilerParams, and a third name would
        # resolve neither branch
        "tpu_compiler_params": lambda: type(
            tpu_compiler_params(dimension_semantics=("arbitrary",))
        ).__name__,
        "set_mesh": lambda: "jax.set_mesh" if hasattr(jax, "set_mesh")
        else "Mesh-as-context-manager (0.4.x)",
        "make_mesh.devices": lambda: "devices= kwarg"
        if _MAKE_MESH_HAS_DEVICES else "Mesh(np.reshape) fallback",
        "make_mesh.axis_types": lambda: "axis_types=Auto"
        if HAS_AXIS_TYPE else "implicit Auto (0.4.x)",
        "shard_map": lambda:
            f"{_SHARD_MAP.__module__}.{_SHARD_MAP.__name__}",
        "shard_map.check_kwarg": lambda:
            _SHARD_MAP_CHECK_KWARG or "no replication-check kwarg",
    }
    report = {}
    for name, probe in checks.items():
        try:
            report[name] = f"OK: {probe()}"
        except Exception as e:                      # pragma: no cover
            report[name] = f"FAIL: {type(e).__name__}: {e}"
    return report
