"""JAX version-compat shims (feature-detected, no version-string parsing).

Supported range: JAX 0.4.37 – 0.6.x. Policy (see ROADMAP.md "Open items"):
every API that was renamed/added across that range is resolved HERE, once,
by feature detection — call sites import from ``repro.compat`` and never
touch ``hasattr`` themselves. Shims are detected at import time so a
missing symbol fails loudly and early, not mid-kernel.

Current shims:

* ``tpu_compiler_params`` — ``pltpu.TPUCompilerParams`` (<= 0.4.x) was
  renamed ``pltpu.CompilerParams`` (>= 0.5). Both take the same
  ``dimension_semantics=...`` kwargs we use.
* ``make_mesh`` — ``jax.make_mesh`` grew an ``axis_types=`` kwarg (and
  ``jax.sharding.AxisType``) in 0.5. On older JAX every axis is already
  implicitly Auto, so dropping the kwarg is semantics-preserving.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.experimental.pallas import tpu as pltpu

# --------------------------------------------------------------------------
# Pallas TPU compiler params: CompilerParams (new) vs TPUCompilerParams (old)
# --------------------------------------------------------------------------

if hasattr(pltpu, "CompilerParams"):
    _COMPILER_PARAMS_CLS = pltpu.CompilerParams
else:
    _COMPILER_PARAMS_CLS = pltpu.TPUCompilerParams


def tpu_compiler_params(
        *, dimension_semantics: Optional[Tuple[str, ...]] = None,
        **kwargs: Any):
    """Version-portable ``compiler_params=`` value for ``pl.pallas_call``."""
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = dimension_semantics
    return _COMPILER_PARAMS_CLS(**kwargs)


# --------------------------------------------------------------------------
# Mesh construction: axis_types= only exists on JAX >= 0.5
# --------------------------------------------------------------------------

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing `mesh` as the ambient mesh.

    ``jax.set_mesh`` appeared in 0.5; on 0.4.x ``Mesh`` itself is the
    context manager with the same enter/exit semantics.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              ) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with every axis explicitly Auto where the concept
    exists (JAX >= 0.5) and implicitly Auto where it doesn't (0.4.x)."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))
