"""host-sync: protect the one-host-sync-per-decode-step contract.

The serving loop's latency story (PR 1, re-defended by hand in PRs 4 and
6) is that exactly ONE device->host synchronization happens per decode
step — the `jax.device_get((toks, done))` after the step program. Every
other sync is either a latency regression (host blocks mid-pipeline) or,
inside a traced function, a silent trace-time concretization that turns
a traced operand into a baked-in constant (= one retrace per value).

Two sub-patterns:

* **sync-point** (host code under src/, outside any traced body): calls
  to ``jax.device_get`` / ``jax.block_until_ready`` /
  ``x.block_until_ready()`` / ``x.item()``. Every one of these is an
  architectural event: the blessed per-step sync and the timed
  benchmarks carry an inline ``# repro-lint: disable=host-sync`` marker
  with a one-line justification; an unmarked sync is a finding. Scoped
  out of tests/ and benchmarks/ (measurement code syncs on purpose,
  per-call).

* **in-trace** (inside bodies resolved as traced/kernel by the module
  model — see modmodel.py): the sync calls above, plus
  ``int()/float()/bool()/np.asarray()`` coercions of array-valued
  expressions, plus Python ``if``/``while`` on array-valued tests
  (including ``jnp.any(...)``-style reductions in the test) — each of
  these either aborts tracing (TracerBoolConversionError) or silently
  constant-folds a traced value at trace time. Array-valuedness is
  inferred per function (names assigned from jnp/lax expressions);
  static config operands never trigger it.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..core import FileContext, Finding, Rule, register
from ..modmodel import dotted

_SYNC_DOTTED = {"jax.device_get", "jax.block_until_ready"}
_SYNC_METHODS = {"block_until_ready", "item"}
_COERCIONS = {"int", "float", "bool"}
_NP_COERCIONS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}


def _sync_call(node: ast.Call):
    """(spelling, True) if `node` is an explicit device->host sync."""
    d = dotted(node.func)
    if d in _SYNC_DOTTED:
        return d
    if isinstance(node.func, ast.Attribute) \
            and node.func.attr in _SYNC_METHODS and not node.args:
        return f".{node.func.attr}()"
    return None


@register
class HostSyncRule(Rule):
    id = "host-sync"
    summary = ("one host sync per decode step: unmarked device_get/"
               "block_until_ready/.item() in engine code, and tracer "
               "coercions (int/bool/np.asarray, if/while on arrays) "
               "inside jitted/shard_mapped/pallas bodies")
    # measurement code (tests, benches, demos) syncs deliberately and
    # per-call — the sync-point sub-pattern would be pure noise there.
    # The in-trace sub-pattern still applies everywhere via check().
    _HOST_SCOPE_SKIP = ("tests", "benchmarks", "examples", "experiments")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        model = ctx.model
        in_trace = model.traced_nodes()

        # -- sub-pattern: sync points in host-side engine code ----------
        if not ctx.in_dir(*self._HOST_SCOPE_SKIP):
            for node in ast.walk(ctx.tree):
                if isinstance(node, ast.Call) and id(node) not in in_trace:
                    spelling = _sync_call(node)
                    if spelling:
                        yield Finding(
                            ctx.path, node.lineno, node.col_offset, self.id,
                            f"host sync `{spelling}` — the engine contract "
                            "is ONE sync per decode step; if this one is "
                            "deliberate, mark it with a justification "
                            "comment")

        # -- sub-pattern: concretization inside traced bodies -----------
        for root, kind in model.trace_roots():
            tracked: Set[str] = model.array_names(root)
            where = "Pallas kernel body" if kind == "kernel" \
                else "traced function"
            for node in ast.walk(root):
                if isinstance(node, ast.Call):
                    spelling = _sync_call(node)
                    if spelling:
                        yield Finding(
                            ctx.path, node.lineno, node.col_offset, self.id,
                            f"`{spelling}` inside a {where} — syncs at "
                            "trace time, constant-folding the traced "
                            "value (one retrace per distinct value)")
                        continue
                    yield from self._check_coercion(
                        ctx, node, tracked, where, model)
                elif isinstance(node, (ast.If, ast.While)):
                    if model.is_array_expr(node.test, tracked):
                        kw = "while" if isinstance(node, ast.While) \
                            else "if"
                        yield Finding(
                            ctx.path, node.lineno, node.col_offset, self.id,
                            f"Python `{kw}` on an array-valued test inside "
                            f"a {where} — tracers have no truth value; use "
                            "jnp.where / lax.cond / lax.select")

    def _check_coercion(self, ctx, node: ast.Call, tracked, where,
                        model) -> Iterator[Finding]:
        d = dotted(node.func)
        name = node.func.id if isinstance(node.func, ast.Name) else None
        if not node.args:
            return
        arg = node.args[0]
        if name in _COERCIONS and model.is_array_expr(arg, tracked):
            yield Finding(
                ctx.path, node.lineno, node.col_offset, self.id,
                f"`{name}()` on an array-valued operand inside a {where} "
                "— concretizes the tracer (sync or TracerError); keep it "
                "an array or hoist the value to a static operand")
        elif d in _NP_COERCIONS and model.is_array_expr(arg, tracked):
            yield Finding(
                ctx.path, node.lineno, node.col_offset, self.id,
                f"`{d}()` on an array-valued operand inside a {where} — "
                "numpy coercion forces a device sync at trace time; use "
                "jnp equivalents on traced values")
