"""Rule modules. Importing this package registers every rule.

Adding a rule is one module here: subclass ``core.Rule``, decorate with
``@core.register``, add the module to the import list below, and give it
a fixture pair in tests/test_lint_rules.py (one flagged, one clean, one
suppressed). Nothing else to touch — the CLI, JSON output, baseline and
CI step pick new rules up from the registry.
"""
from . import compat_policy   # noqa: F401
from . import host_sync       # noqa: F401
from . import retrace_hazard  # noqa: F401
from . import kernel_purity   # noqa: F401
