"""kernel-purity: Pallas kernel bodies stay on-device and static-shaped.

A kernel body (any def the module model resolves as wrapped by
``pl.pallas_call``, incl. ``functools.partial``-bound kernels and their
in-module helpers) executes per grid step on the core. Host-side
constructs there either fail at lowering or — worse, in interpret mode —
silently work on CPU and then explode on TPU, which is exactly the class
of bug the CPU-interpret CI contract cannot catch. Flags:

* ``numpy`` calls (``np.*``): host arrays in a device body. Trace-time
  constants belong outside the kernel, passed via closure/partial.
* ``print(...)``: host I/O; use ``pl.debug_print`` which lowers.
* host callbacks: ``jax.pure_callback`` / ``jax.debug.callback`` /
  ``jax.debug.print`` / ``io_callback`` — none lower inside a kernel.
* reductions over **dynamically-shaped** slices: ``jnp.sum(x[a:n])`` or
  ``pl.ds(start, size)`` where the bound/size is a value loaded from a
  Ref or derived from ``pl.program_id`` — Pallas block shapes are
  static; dynamic extents must be expressed as masks over a static
  shape (the online-softmax kernels' ``pos < length`` idiom).
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..core import FileContext, Finding, Rule, register
from ..modmodel import dotted

_CALLBACKS = {
    "jax.pure_callback", "jax.debug.callback", "jax.debug.print",
    "jax.experimental.io_callback", "io_callback", "pure_callback",
}
_REDUCTIONS = {
    "sum", "max", "min", "mean", "prod", "any", "all", "argmax",
    "argmin", "cumsum", "cumprod",
}
_DS_NAMES = {"pl.ds", "pl.dslice"}


def _kernel_dynamic_names(root: ast.AST) -> Set[str]:
    """Names holding per-grid-step traced values inside a kernel body:
    loads from Ref params (``x_ref[...]``), ``pl.program_id`` results,
    and arithmetic derived from either. Static tile sizes arrive as
    partial-bound python ints and never enter this set."""
    params: Set[str] = set()
    if isinstance(root, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        a = root.args
        for p in list(a.args) + list(a.posonlyargs) + list(a.kwonlyargs):
            params.add(p.arg)

    def dynamic(expr: ast.AST, tracked: Set[str]) -> bool:
        if isinstance(expr, ast.Name):
            return expr.id in tracked
        if isinstance(expr, ast.Subscript):
            base = expr.value
            return isinstance(base, ast.Name) and base.id in params
        if isinstance(expr, ast.Call):
            d = dotted(expr.func)
            if d in ("pl.program_id", "pl.num_programs"):
                return True
            if d in ("pl.load",) and expr.args:
                return dynamic(expr.args[0], tracked) or (
                    isinstance(expr.args[0], ast.Name)
                    and expr.args[0].id in params)
            return False
        if isinstance(expr, ast.BinOp):
            return dynamic(expr.left, tracked) or dynamic(expr.right,
                                                          tracked)
        if isinstance(expr, ast.UnaryOp):
            return dynamic(expr.operand, tracked)
        return False

    tracked: Set[str] = set()
    for _ in range(8):
        grew = False
        for node in ast.walk(root):
            if isinstance(node, ast.Assign) and dynamic(node.value, tracked):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id not in tracked:
                        tracked.add(tgt.id)
                        grew = True
        if not grew:
            break
    return tracked


@register
class KernelPurityRule(Rule):
    id = "kernel-purity"
    summary = ("Pallas kernel bodies: no numpy/print/host callbacks, no "
               "reductions over dynamically-shaped slices (mask a static "
               "shape instead)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for root, kind in ctx.model.trace_roots():
            if kind != "kernel":
                continue
            dyn = _kernel_dynamic_names(root)
            for node in ast.walk(root):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d and d.split(".")[0] in ("np", "numpy"):
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, self.id,
                        f"`{d}(...)` inside a Pallas kernel body — numpy "
                        "is host-side; compute trace-time constants "
                        "outside the kernel and close over them")
                elif d == "print":
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, self.id,
                        "`print()` inside a Pallas kernel body — host "
                        "I/O does not lower; use pl.debug_print")
                elif d in _CALLBACKS:
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, self.id,
                        f"host callback `{d}` inside a Pallas kernel "
                        "body — callbacks do not lower inside kernels")
                else:
                    yield from self._check_dynamic_shape(ctx, node, dyn)

    def _check_dynamic_shape(self, ctx, node: ast.Call,
                             dyn: Set[str]) -> Iterator[Finding]:
        d = dotted(node.func)
        # pl.ds(start, SIZE): traced start is the point of pl.ds; a
        # traced SIZE is a dynamic shape
        if d in _DS_NAMES and len(node.args) >= 2:
            size = node.args[1]
            if self._is_dyn(size, dyn):
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, self.id,
                    f"`{d}` with a traced size — Pallas extents are "
                    "static; keep the size static and mask the tail")
            return
        # jnp.<reduction>(x[a:b]) with a traced bound
        if not (d and d.startswith("jnp.")
                and d.split(".")[-1] in _REDUCTIONS and node.args):
            return
        arg = node.args[0]
        if isinstance(arg, ast.Subscript):
            sl = arg.slice
            bounds = []
            if isinstance(sl, ast.Slice):
                bounds = [sl.lower, sl.upper]
            elif isinstance(sl, ast.Tuple):
                for el in sl.elts:
                    if isinstance(el, ast.Slice):
                        bounds += [el.lower, el.upper]
            if any(b is not None and self._is_dyn(b, dyn) for b in bounds):
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, self.id,
                    f"`{d}` over a dynamically-shaped slice — the "
                    "extent is a traced value; reduce over the static "
                    "block and mask rows past the live extent")

    @staticmethod
    def _is_dyn(expr: ast.AST, dyn: Set[str]) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Name) and n.id in dyn:
                return True
            if isinstance(n, ast.Call) and dotted(n.func) in (
                    "pl.program_id", "pl.num_programs"):
                return True
        return False
