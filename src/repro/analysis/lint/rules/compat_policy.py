"""compat-policy: version feature-detection lives in compat.py, nowhere
else.

The invariant (ROADMAP.md "JAX version support & compat-shim policy",
established in PR 1): every API that changed across the supported JAX
range is resolved ONCE, by feature detection, in ``src/repro/compat.py``
— call sites import the shim. ``hasattr(jax, ...)`` at a call site means
the next rename fails mid-kernel instead of at import; version-string
comparison breaks on prereleases and is banned outright.

Flags, outside compat.py:

* ``hasattr(<jax-ish module>, ...)``
* 3-arg ``getattr(<jax-ish module>, ..., default)`` (the probing form;
  2-arg getattr on runtime objects is ordinary duck typing and is fine)
* any use of ``jax.__version__`` / ``jaxlib.__version__`` etc.
* ``importlib.metadata.version("jax"/"jaxlib")`` probes

"jax-ish module" = a name chain rooted at jax / jnp / lax / pl / pltpu /
pallas / jaxlib — the conventional import spellings this repo uses.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..core import FileContext, Finding, Rule, register
from ..modmodel import call_root

_JAX_ROOTS = {"jax", "jnp", "lax", "pl", "pltpu", "pallas", "jaxlib"}


def _is_compat_module(ctx: FileContext) -> bool:
    # the one file allowed to probe: src/repro/compat.py (fixtures named
    # compat.py under a repro/ dir count too, so tests can exercise the
    # exemption without a full tree)
    return ctx.parts[-1] == "compat.py" and "repro" in ctx.parts


@register
class CompatPolicyRule(Rule):
    id = "compat-policy"
    summary = ("jax/pltpu/pallas feature probes and version checks belong "
               "in src/repro/compat.py only (ROADMAP compat-shim policy)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if _is_compat_module(ctx):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(ctx, node)
            elif isinstance(node, ast.Attribute):
                if node.attr == "__version__" \
                        and call_root(node) in _JAX_ROOTS:
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, self.id,
                        f"version check on `{call_root(node)}.__version__`"
                        " — feature-detect in compat.py instead (version"
                        " strings lie on prereleases)")

    def _check_call(self, ctx: FileContext,
                    node: ast.Call) -> Iterator[Finding]:
        fname = node.func.id if isinstance(node.func, ast.Name) else None
        if fname == "hasattr" and node.args:
            root = call_root(node.args[0])
            if root in _JAX_ROOTS:
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, self.id,
                    f"`hasattr({root}, ...)` outside compat.py — move the"
                    " feature probe into a compat.py shim and import it")
        elif fname == "getattr" and len(node.args) >= 3:
            root = call_root(node.args[0])
            if root in _JAX_ROOTS:
                yield Finding(
                    ctx.path, node.lineno, node.col_offset, self.id,
                    f"probing `getattr({root}, ..., default)` outside"
                    " compat.py — move the feature probe into a compat.py"
                    " shim and import it")
        else:
            d_parts = []
            n = node.func
            while isinstance(n, ast.Attribute):
                d_parts.append(n.attr)
                n = n.value
            if isinstance(n, ast.Name):
                d_parts.append(n.id)
            d = ".".join(reversed(d_parts))
            if d.endswith("metadata.version") or d == "version":
                if node.args and isinstance(node.args[0], ast.Constant) \
                        and str(node.args[0].value) in ("jax", "jaxlib"):
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, self.id,
                        f"package-version probe for "
                        f"'{node.args[0].value}' outside compat.py — "
                        "feature-detect the API instead")
