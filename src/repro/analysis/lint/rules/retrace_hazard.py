"""retrace-hazard: keep jitted entry points to one trace per shape class.

The engine's trace economy (one trace per prefill bucket, one decode
trace per policy mix — the `step_traces` telemetry from PR 9 watches it
at runtime) dies quietly when a call site hands a jitted function
something that hashes differently every call, or when a jitted closure
reads mutable object state that tracing bakes in as a constant. This
rule catches the static-analysis-visible members of that class:

* **jit-per-call**: ``jax.jit(f)(x)`` inside a function body — a fresh
  jit wrapper (fresh trace cache) is built on every invocation. Hoist
  the wrapper to module/init scope. Module-level one-shots are fine.

* **unhashable-static**: a call to a known jitted binding (``f = jax.jit
  (..., static_argnums/names=...)`` or a ``@partial(jax.jit, ...)`` def
  in the same module) passing, in a static position, a list/dict/set
  display (TypeError at runtime) or a freshly-constructed object
  (identity-hashed unless the class defines __eq__/__hash__ — one
  retrace per call).

* **self-capture**: a traced closure reading ``self.<attr>``. Tracing
  captures the attribute's value at trace time; mutating it later
  silently does nothing (or forces a retrace if it feeds shapes). The
  engine idiom is to hoist ``self`` reads into factory locals before the
  closure (see serving.py's ``_make_*`` methods); the deliberate
  trace-time telemetry counters carry inline markers.

Scoped out of tests/ and benchmarks/: a test calling ``jax.jit(f)(x)``
once is not a serving-path hazard.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..core import FileContext, Finding, Rule, register
from ..modmodel import dotted

_JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}


def _fresh_static(expr: ast.AST) -> str:
    """Why `expr` is a retrace hazard in a static position ('' = fine)."""
    if isinstance(expr, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return "unhashable literal (TypeError as a static operand)"
    if isinstance(expr, ast.Call):
        d = dotted(expr.func) or "<expr>"
        if d in ("tuple", "frozenset", "str", "int", "float", "bool"):
            return ""
        return (f"freshly-constructed `{d}(...)` — identity-hashed "
                "unless the class defines __eq__/__hash__, so every call "
                "retraces")
    if isinstance(expr, ast.Tuple) and any(
            _fresh_static(e) for e in expr.elts):
        return "tuple containing freshly-constructed elements"
    return ""


@register
class RetraceHazardRule(Rule):
    id = "retrace-hazard"
    summary = ("one trace per shape class: no per-call jax.jit wrappers, "
               "no unhashed objects in static positions, no mutable "
               "self.<attr> captured by jitted closures")
    skip_dirs = ("tests", "benchmarks")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        model = ctx.model
        yield from self._jit_per_call(ctx)
        yield from self._static_operands(ctx, model)
        yield from self._self_capture(ctx, model)

    # -- jax.jit(f)(x) inside a function body ---------------------------

    def _jit_per_call(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(fn):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Call)
                        and dotted(node.func.func) in _JIT_NAMES):
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, self.id,
                        "`jax.jit(...)(...)` builds a fresh trace cache "
                        "on every call — hoist the jitted wrapper to "
                        "module or __init__ scope")

    # -- static positions at call sites of known jitted bindings --------

    def _static_operands(self, ctx: FileContext, model) -> Iterator[Finding]:
        bindings = model.jit_bindings
        if not bindings:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = None
            if isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node.func, ast.Attribute):
                name = node.func.attr
            info = bindings.get(name)
            if info is None:
                continue
            for pos in info["static_argnums"]:
                if isinstance(pos, int) and pos < len(node.args):
                    why = _fresh_static(node.args[pos])
                    if why:
                        yield Finding(
                            ctx.path, node.lineno, node.col_offset,
                            self.id,
                            f"static arg {pos} of jitted `{name}`: {why}")
            static_names = set(info["static_argnames"])
            for kw in node.keywords:
                if kw.arg in static_names:
                    why = _fresh_static(kw.value)
                    if why:
                        yield Finding(
                            ctx.path, node.lineno, node.col_offset,
                            self.id,
                            f"static kwarg `{kw.arg}` of jitted "
                            f"`{name}`: {why}")

    # -- traced closures reading self.<attr> ----------------------------

    def _self_capture(self, ctx: FileContext, model) -> Iterator[Finding]:
        for root, kind in model.trace_roots():
            if kind != "trace":
                continue   # kernel refs can't close over self anyway
            # `self.method(...)` is resolved by the transitive-trace
            # model (the method body gets its own findings); attribute
            # READS are the captured-state hazard this flags
            called = {id(n.func) for n in ast.walk(root)
                      if isinstance(n, ast.Call)}
            seen: Set[str] = set()
            for node in ast.walk(root):
                if (isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"
                        and id(node) not in called
                        and node.attr not in seen):
                    seen.add(node.attr)
                    yield Finding(
                        ctx.path, node.lineno, node.col_offset, self.id,
                        f"jitted closure captures `self.{node.attr}` — "
                        "tracing bakes in the value at trace time (later "
                        "mutation is ignored or retraces); hoist it to a "
                        "factory local or pass it as an operand")
        return
