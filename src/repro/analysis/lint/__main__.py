"""CLI: ``python -m repro.analysis.lint [paths] [--rule ...] [--json]``.

Exit codes: 0 = clean (after inline suppressions + baseline), 1 = live
findings, 2 = usage/IO error. Plain output is one ``path:line: rule-id
message`` per finding; ``--json`` emits the machine-readable report the
CI lint job uploads as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import (REGISTRY, baseline_lines, lint_paths)

DEFAULT_BASELINE = ".repro-lint-baseline"


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST trace-safety linter (stdlib-only, no jax "
                    "needed): host-sync, compat-shim, retrace and "
                    "kernel-purity invariants.")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to lint (default: src)")
    p.add_argument("--rule", action="append", dest="rules", metavar="ID",
                   help="run only this rule (repeatable); see "
                        "--list-rules")
    p.add_argument("--json", action="store_true",
                   help="emit the JSON report on stdout instead of "
                        "plain findings")
    p.add_argument("--out", metavar="FILE",
                   help="also write the JSON report to FILE (the CI "
                        "artifact)")
    p.add_argument("--baseline", metavar="FILE", default=None,
                   help=f"baseline file of grandfathered findings "
                        f"(default: ./{DEFAULT_BASELINE} if present)")
    p.add_argument("--write-baseline", action="store_true",
                   help="write current findings to the baseline file "
                        "and exit 0 (ratchet tool; the shipped baseline "
                        "stays empty)")
    p.add_argument("--list-rules", action="store_true",
                   help="print rule ids + summaries and exit")
    return p


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    from . import rules as _rules  # noqa: F401  (populate REGISTRY)

    if args.list_rules:
        for rid, rule in sorted(REGISTRY.items()):
            print(f"{rid}: {rule.summary}")
        return 0

    if args.rules:
        unknown = [r for r in args.rules if r not in REGISTRY]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2

    baseline = args.baseline
    if baseline is None and Path(DEFAULT_BASELINE).is_file():
        baseline = DEFAULT_BASELINE

    try:
        result = lint_paths(args.paths, rules=args.rules,
                            baseline=None if args.write_baseline
                            else baseline)
    except FileNotFoundError as e:
        print(str(e), file=sys.stderr)
        return 2

    if args.write_baseline:
        target = baseline or DEFAULT_BASELINE
        lines = ["# repro-lint baseline: grandfathered findings "
                 "(path|rule|message).",
                 "# Target state is EMPTY — fix the tree instead. See "
                 "DESIGN.md 'Static analysis'."]
        lines += baseline_lines(result.findings)
        Path(target).write_text("\n".join(lines) + "\n")
        print(f"wrote {len(result.findings)} finding(s) to {target}",
              file=sys.stderr)
        return 0

    report = result.to_json()
    if args.out:
        Path(args.out).write_text(json.dumps(report, indent=2) + "\n")
    if args.json:
        print(json.dumps(report, indent=2))
    elif result.findings:
        print(result.render())
    n = len(result.findings)
    print(f"repro-lint: {n} finding(s) in {result.files} file(s) "
          f"({result.suppressed} suppressed inline, "
          f"{result.baselined} baselined)", file=sys.stderr)
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
