"""repro-lint core: findings, rule registry, suppressions, baseline, runner.

Stdlib-only on purpose (``ast`` + friends): the linter never imports the
code it scans, so it runs on a checkout with **no jax installed** — the CI
lint job asserts exactly that — and behaves identically on the 0.4.37
floor and latest. Rules live in ``repro.analysis.lint.rules`` and register
themselves via :func:`register`; adding a rule is one module with one
class (see rules/__init__.py).

Finding format (one per line, ruff/gcc style, clickable in editors)::

    path:line: <rule-id> message

Suppression: a ``# repro-lint: disable=<rule>[,<rule>...]`` (or
``disable=all``) comment on the finding's line, or alone on the line
directly above it, silences the finding. Deliberate violations (e.g. the
one blessed host sync per decode step) carry a marker plus a one-line
justification; everything else is a lint failure.

Baseline: a checked-in file of line-number-free fingerprints
(``path|rule|message``) for grandfathered findings. The shipped baseline
is EMPTY — the policy is to fix the tree, not to grandfather — but the
mechanism exists so a future sweep that lands a new rule against old code
can ratchet instead of big-banging.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path, PurePosixPath
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

__all__ = [
    "Finding", "Rule", "FileContext", "LintResult", "REGISTRY", "register",
    "lint_source", "lint_paths", "iter_py_files", "load_baseline",
    "baseline_lines",
]


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline file (line
        numbers churn on every unrelated edit; path+rule+message is
        stable until the violation itself changes)."""
        return f"{self.path}|{self.rule}|{self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """Everything a rule needs about one file: parsed tree, source lines,
    path split into parts (for scope checks), and the lazily-built module
    model shared by the trace-aware rules."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parts = PurePosixPath(path.replace("\\", "/")).parts
        self._model = None

    @property
    def model(self):
        """ModuleModel (see modmodel.py), built once per file on first
        use by a trace-aware rule."""
        if self._model is None:
            from .modmodel import ModuleModel
            self._model = ModuleModel(self.tree)
        return self._model

    def in_dir(self, *names: str) -> bool:
        """True if any path component matches one of ``names`` — how
        rules scope themselves out of tests/ or benchmarks/."""
        return bool(set(self.parts[:-1]) & set(names))


class Rule:
    """Base class for lint rules. Subclasses set ``id``/``summary``,
    optionally ``skip_dirs`` (path components the rule never applies
    under), and implement ``check``."""

    id: str = ""
    summary: str = ""
    #: path components (directory names) this rule is scoped OUT of —
    #: e.g. retrace hazards only matter for code that serves traffic,
    #: so that rule skips tests/ and benchmarks/.
    skip_dirs: Sequence[str] = ()

    def applies_to(self, ctx: FileContext) -> bool:
        return not (self.skip_dirs and ctx.in_dir(*self.skip_dirs))

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError
        yield  # pragma: no cover


#: rule-id -> Rule instance. Populated by importing the rules package.
REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule to the registry (one instance,
    stateless between files)."""
    assert cls.id and cls.id not in REGISTRY, cls
    REGISTRY[cls.id] = cls()
    return cls


def _ensure_rules_loaded() -> None:
    if not REGISTRY:
        from . import rules  # noqa: F401  (import registers the rules)


# --------------------------------------------------------------------------
# Suppression comments
# --------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_\-, ]+)")


def suppressions(source_lines: List[str]) -> Dict[int, Set[str]]:
    """1-based line number -> set of suppressed rule ids ('all' wildcard
    included verbatim)."""
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source_lines, start=1):
        m = _SUPPRESS_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _is_suppressed(f: Finding, sup: Dict[int, Set[str]],
                   lines: List[str]) -> bool:
    for ln in (f.line, f.line - 1):
        rules = sup.get(ln)
        if not rules:
            continue
        if ln != f.line:
            # a comment on the previous line only counts if that line is
            # comment-only — a trailing marker belongs to ITS statement
            prev = lines[ln - 1].strip() if 0 < ln <= len(lines) else ""
            if not prev.startswith("#"):
                continue
        if "all" in rules or f.rule in rules:
            return True
    return False


# --------------------------------------------------------------------------
# File discovery (gitignore-aware, no git needed)
# --------------------------------------------------------------------------

#: always skipped regardless of .gitignore — cache/VCS litter
ALWAYS_SKIP_DIRS = {
    "__pycache__", ".git", ".hg", ".svn", ".ruff_cache", ".pytest_cache",
    ".hypothesis", ".mypy_cache", ".venv", "venv", "node_modules",
}


def _gitignore_patterns(root: Path) -> tuple[Set[str], Set[str]]:
    """(dir names, file suffixes) from the root .gitignore — a deliberate
    subset of gitignore syntax covering what this repo uses: bare names /
    ``name/`` / ``**/name/`` become directory-name skips, ``*.ext``
    becomes a suffix skip. Negations and nested patterns are out of scope
    (the linter only needs to not descend into ignored litter)."""
    dirs: Set[str] = set()
    suffixes: Set[str] = set()
    gi = root / ".gitignore"
    if not gi.is_file():
        return dirs, suffixes
    for raw in gi.read_text().splitlines():
        pat = raw.strip()
        if not pat or pat.startswith("#") or pat.startswith("!"):
            continue
        if pat.startswith("**/"):
            pat = pat[3:]
        if pat.startswith("*."):
            suffixes.add(pat[1:])           # "*.pyc" -> ".pyc"
        elif "/" not in pat.rstrip("/"):
            dirs.add(pat.rstrip("/"))
    return dirs, suffixes


def iter_py_files(paths: Sequence[str],
                  root: Optional[Path] = None) -> Iterator[Path]:
    """Yield .py files under ``paths`` (files or directories), skipping
    __pycache__ / hidden caches / anything the root .gitignore names."""
    root = Path(root) if root is not None else Path.cwd()
    skip_dirs, skip_suffixes = _gitignore_patterns(root)
    skip_dirs |= ALWAYS_SKIP_DIRS

    def walk(p: Path) -> Iterator[Path]:
        if p.is_file():
            if p.suffix == ".py" and p.suffix not in skip_suffixes:
                yield p
            return
        if not p.is_dir():
            raise FileNotFoundError(f"no such file or directory: {p}")
        for child in sorted(p.iterdir()):
            if child.name in skip_dirs or child.name.startswith("."):
                continue
            yield from walk(child)

    for p in paths:
        yield from walk(Path(p))


# --------------------------------------------------------------------------
# Baseline
# --------------------------------------------------------------------------

def load_baseline(path: Optional[str]) -> Set[str]:
    """Fingerprint set from a baseline file; missing file = empty."""
    if not path or not Path(path).is_file():
        return set()
    out = set()
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def baseline_lines(findings: Iterable[Finding]) -> List[str]:
    return sorted({f.fingerprint() for f in findings})


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------

@dataclasses.dataclass
class LintResult:
    findings: List[Finding]          # live findings (fail the run)
    suppressed: int                  # silenced by inline markers
    baselined: int                   # silenced by the baseline file
    files: int                       # files scanned

    def to_json(self) -> dict:
        counts: Dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        _ensure_rules_loaded()
        return {
            "version": 1,
            "tool": "repro-lint",
            "files": self.files,
            "suppressed": self.suppressed,
            "baselined": self.baselined,
            "counts": counts,
            "rules": {rid: r.summary for rid, r in sorted(REGISTRY.items())},
            "findings": [f.to_json() for f in sorted(self.findings)],
        }

    def render(self) -> str:
        return "\n".join(f.render() for f in sorted(self.findings))


def lint_source(source: str, path: str = "<string>",
                rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one source string. Inline suppressions are honored; the
    baseline is not consulted (that's a repo-level concern). Unknown rule
    ids raise KeyError — a typo'd --rule must not silently pass."""
    _ensure_rules_loaded()
    active = [REGISTRY[r] for r in rules] if rules \
        else list(REGISTRY.values())
    try:
        ctx = FileContext(path, source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, 0, "parse-error",
                        f"could not parse: {e.msg}")]
    sup = suppressions(ctx.lines)
    out: Set[Finding] = set()
    for rule in active:
        if not rule.applies_to(ctx):
            continue
        for f in rule.check(ctx):
            if not _is_suppressed(f, sup, ctx.lines):
                out.add(f)
    return sorted(out)


def _lint_file(path: Path, rules: Optional[Sequence[str]],
               rel_to: Path) -> tuple[List[Finding], int]:
    """(live findings, inline-suppressed count) for one file."""
    _ensure_rules_loaded()
    try:
        rel = str(path.relative_to(rel_to))
    except ValueError:
        rel = str(path)
    source = path.read_text()
    active = [REGISTRY[r] for r in rules] if rules \
        else list(REGISTRY.values())
    try:
        ctx = FileContext(rel, source)
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 1, 0, "parse-error",
                        f"could not parse: {e.msg}")], 0
    sup = suppressions(ctx.lines)
    live: Set[Finding] = set()
    n_sup = 0
    for rule in active:
        if not rule.applies_to(ctx):
            continue
        for f in rule.check(ctx):
            if _is_suppressed(f, sup, ctx.lines):
                n_sup += 1
            else:
                live.add(f)
    return sorted(live), n_sup


def lint_paths(paths: Sequence[str], *,
               rules: Optional[Sequence[str]] = None,
               baseline: Optional[str] = None,
               root: Optional[Path] = None) -> LintResult:
    """Lint every .py file under ``paths``; the public entry the CLI and
    the tests share."""
    root = Path(root) if root is not None else Path.cwd()
    base = load_baseline(baseline)
    findings: List[Finding] = []
    n_sup = n_base = n_files = 0
    for p in iter_py_files(paths, root=root):
        n_files += 1
        live, sup = _lint_file(p, rules, rel_to=root)
        n_sup += sup
        for f in live:
            if f.fingerprint() in base:
                n_base += 1
            else:
                findings.append(f)
    return LintResult(sorted(findings), n_sup, n_base, n_files)
