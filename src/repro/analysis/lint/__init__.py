"""repro-lint: AST trace-safety linter for the serving engine's invariants.

Stdlib-``ast`` static analysis — **no jax import, ever** (the CI lint job
runs it on a jax-less interpreter and asserts that) — enforcing the
contracts the engine's performance story depends on:

* ``compat-policy``   — feature detection lives in src/repro/compat.py
                        only (ROADMAP compat-shim policy, PR 1).
* ``host-sync``       — one device->host sync per decode step; no tracer
                        concretization inside traced bodies (PRs 1/4/6).
* ``retrace-hazard``  — one trace per shape class: no per-call jit
                        wrappers, no unhashed static operands, no mutable
                        ``self`` capture (the PR 9 ``step_traces``
                        telemetry's static twin).
* ``kernel-purity``   — Pallas kernel bodies stay on-device and
                        static-shaped (PR 2's kernels; CPU-interpret CI
                        can't catch these, lowering can).

Run: ``python -m repro.analysis.lint [paths] [--rule R] [--json]``
(mirrors ``python -m repro.runtime.trace --validate``). Suppress a
deliberate violation with ``# repro-lint: disable=<rule>`` plus a
justification on the same line or the comment line above. DESIGN.md
"Static analysis" documents each rule and the invariant's origin.
"""
from .core import (Finding, LintResult, REGISTRY, Rule, baseline_lines,
                   iter_py_files, lint_paths, lint_source, load_baseline,
                   register)
from . import rules  # noqa: F401  (registers the rule set on import)

__all__ = [
    "Finding", "LintResult", "REGISTRY", "Rule", "baseline_lines",
    "iter_py_files", "lint_paths", "lint_source", "load_baseline",
    "register", "main",
]


def main(argv=None) -> int:
    from .__main__ import main as _main
    return _main(argv)
