"""Module model for the trace-aware rules: which functions are traced?

The host-sync / retrace-hazard / kernel-purity rules all need the same
question answered from a single module's AST: *which function bodies run
under a trace* (``jax.jit`` / ``compat.shard_map``) *or inside a Pallas
kernel* (``pl.pallas_call``)? Per the engine's architecture (DESIGN.md
"Static analysis") the answer is resolvable module-locally, because every
traced program is built where it is jitted:

* direct wrap: ``jax.jit(fn)`` / ``shard_map(fn, ...)`` /
  ``pl.pallas_call(kernel, ...)`` with ``fn`` a module-level or nested def;
* decorator: ``@jax.jit`` or ``@functools.partial(jax.jit, ...)``;
* partial: ``pl.pallas_call(functools.partial(kernel, page=8), ...)``;
* factory: ``self._step_fn = jax.jit(self._make_step())`` — the serving
  engines' idiom — resolved by finding ``_make_step`` in the module and
  marking the nested def(s) it ``return``s;
* transitively: any function a traced function calls by name, when that
  name resolves to a def in the same module (cross-module calls are out
  of scope by design — the callee module gets its own model).

Everything here is a heuristic over names, not an import-time analysis —
that is the point: no jax required, identical on every JAX version.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

__all__ = ["ModuleModel", "FuncInfo", "dotted", "call_root",
           "STATIC_JNP_HELPERS"]

#: spellings that introduce a TRACE boundary (the wrapped callable's body
#: executes under jax tracing) — matched against the literal dotted name
#: AND its import-alias-canonicalized form (so ``from repro.compat import
#: shard_map as _smap`` still classifies)
_JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}
_SHARD_NAMES = {"compat.shard_map", "shard_map", "repro.compat.shard_map",
                "jax.experimental.shard_map.shard_map", "jax.shard_map"}
#: spellings that introduce a KERNEL body (Pallas)
_KERNEL_NAMES = {"pl.pallas_call", "pallas_call",
                 "jax.experimental.pallas.pallas_call"}
_PARTIAL_NAMES = {"functools.partial", "partial"}

#: jnp helpers that return static python values, not traced arrays —
#: excluded from "array-valued expression" inference so
#: ``if jnp.issubdtype(...)`` is not a tracer-bool false positive
STATIC_JNP_HELPERS = {
    "issubdtype", "isdtype", "result_type", "promote_types", "can_cast",
    "iinfo", "finfo", "dtype", "shape", "ndim",
}


def dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute/Name chains; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_root(node: ast.AST) -> Optional[str]:
    """Leftmost name of an Attribute/Name chain ('jax' for jax.lax.scan)."""
    d = dotted(node)
    return d.split(".")[0] if d else None


class FuncInfo:
    """One def (module-level, method, or nested) plus resolution data."""

    __slots__ = ("node", "name", "parent", "cls", "nested", "returned",
                 "kind")

    def __init__(self, node: ast.AST, name: str,
                 parent: Optional["FuncInfo"], cls: Optional[str]):
        self.node = node
        self.name = name
        self.parent = parent
        self.cls = cls
        self.nested: Dict[str, List["FuncInfo"]] = {}
        self.returned: Set[str] = set()    # names of nested defs returned
        self.kind: Optional[str] = None    # None | "trace" | "kernel"

    def ancestors(self) -> Iterator["FuncInfo"]:
        p = self.parent
        while p is not None:
            yield p
            p = p.parent


class _Collector(ast.NodeVisitor):
    """Pass 1: index every def (nested included) + which nested defs each
    def returns."""

    def __init__(self):
        self.funcs: List[FuncInfo] = []
        self.by_name: Dict[str, List[FuncInfo]] = {}
        self.by_node: Dict[ast.AST, FuncInfo] = {}
        self._stack: List[FuncInfo] = []
        self._cls: List[str] = []

    def _def(self, node):
        parent = self._stack[-1] if self._stack else None
        cls = self._cls[-1] if self._cls else None
        info = FuncInfo(node, node.name, parent, cls)
        self.funcs.append(info)
        self.by_name.setdefault(node.name, []).append(info)
        self.by_node[node] = info
        if parent is not None:
            parent.nested.setdefault(node.name, []).append(info)
        self._stack.append(info)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _def
    visit_AsyncFunctionDef = _def

    def visit_ClassDef(self, node: ast.ClassDef):
        self._cls.append(node.name)
        self.generic_visit(node)
        self._cls.pop()

    def visit_Return(self, node: ast.Return):
        if (self._stack and isinstance(node.value, ast.Name)
                and node.value.id in self._stack[-1].nested):
            self._stack[-1].returned.add(node.value.id)
        self.generic_visit(node)


class ModuleModel:
    """Resolved trace structure of one module. Public surface:

    * ``trace_roots()`` — outermost (FuncInfo-or-Lambda, kind) pairs whose
      bodies run traced; kind is "trace" or "kernel".
    * ``jit_bindings`` — name -> static-operand info for jitted callables
      bound in this module (``f = jax.jit(..., static_argnames=...)`` or
      decorated defs), consumed by the retrace rule.
    """

    def __init__(self, tree: ast.Module):
        self.tree = tree
        c = _Collector()
        c.visit(tree)
        self._funcs = c.funcs
        self._by_name = c.by_name
        self._by_node = c.by_node
        self._traced_lambdas: Dict[ast.Lambda, str] = {}
        #: local name -> canonical dotted origin, from import statements
        #: (``from repro.compat import shard_map as _smap`` ->
        #: {"_smap": "repro.compat.shard_map"})
        self._alias: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self._alias[a.asname or a.name] = \
                        f"{node.module}.{a.name}"
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self._alias[a.asname] = a.name
        #: binding name -> {"static_argnums": tuple, "static_argnames":
        #: tuple, "line": int}
        self.jit_bindings: Dict[str, dict] = {}
        self._find_wrap_sites(tree)
        self._close_transitively()

    def _canon(self, d: Optional[str]) -> Optional[str]:
        """Dotted name with its leading segment resolved through the
        module's import aliases."""
        if not d:
            return d
        head, _, rest = d.partition(".")
        origin = self._alias.get(head)
        if origin:
            return f"{origin}.{rest}" if rest else origin
        return d

    # -- wrap-site discovery ---------------------------------------------

    def _classify(self, func_expr: ast.AST) -> Optional[str]:
        d = dotted(func_expr)
        for name in (d, self._canon(d)):
            if name in _JIT_NAMES or name in _SHARD_NAMES:
                return "trace"
            if name in _KERNEL_NAMES:
                return "kernel"
        return None

    def _resolve(self, expr: ast.AST) -> List[FuncInfo]:
        """Defs a wrap-site argument refers to: Name, self.attr/mod.attr
        (bare-name match), partial(fn, ...), or factory() -> returned
        nested defs."""
        if isinstance(expr, ast.Name):
            return self._by_name.get(expr.id, [])
        if isinstance(expr, ast.Attribute):
            return self._by_name.get(expr.attr, [])
        if isinstance(expr, ast.Call):
            d = dotted(expr.func)
            if (d in _PARTIAL_NAMES or self._canon(d) in _PARTIAL_NAMES) \
                    and expr.args:
                return self._resolve(expr.args[0])
            out: List[FuncInfo] = []
            for factory in self._resolve(expr.func):
                for name in factory.returned:
                    out.extend(factory.nested.get(name, []))
            return out
        return []

    def _mark(self, expr: ast.AST, kind: str) -> None:
        if isinstance(expr, ast.Lambda):
            self._traced_lambdas[expr] = kind
            return
        for info in self._resolve(expr):
            if info.kind is None:
                info.kind = kind

    @staticmethod
    def _static_info(call: ast.Call) -> dict:
        """Literal static_argnums/static_argnames from a jit call."""
        def tup(v):
            if isinstance(v, ast.Constant):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                return tuple(e.value for e in v.elts
                             if isinstance(e, ast.Constant))
            return ()
        nums: Tuple = ()
        names: Tuple = ()
        for kw in call.keywords:
            if kw.arg == "static_argnums":
                nums = tup(kw.value)
            elif kw.arg == "static_argnames":
                names = tup(kw.value)
        return {"static_argnums": nums, "static_argnames": names,
                "line": call.lineno}

    def _find_wrap_sites(self, tree: ast.Module) -> None:
        # value-node -> binding names, for `x = jax.jit(...)` and
        # `self.x = jax.jit(...)` (retrace rule vets those call sites)
        assigned_names: Dict[int, List[str]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                names = []
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        names.append(tgt.id)
                    elif isinstance(tgt, ast.Attribute):
                        names.append(tgt.attr)
                if names:
                    assigned_names[id(node.value)] = names
        for node in ast.walk(tree):
            # decorators: @jax.jit / @functools.partial(jax.jit, ...)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    kind = self._classify(dec)
                    inner = None
                    if kind is None and isinstance(dec, ast.Call):
                        dfn = dotted(dec.func)
                        if (dfn in _PARTIAL_NAMES or self._canon(dfn)
                                in _PARTIAL_NAMES) and dec.args:
                            kind = self._classify(dec.args[0])
                            inner = dec
                        else:
                            kind = self._classify(dec.func)
                            inner = dec
                    if kind:
                        info = self._by_node[node]
                        if info.kind is None:
                            info.kind = kind
                        if kind == "trace":
                            st = self._static_info(inner) if isinstance(
                                inner, ast.Call) else {
                                "static_argnums": (), "static_argnames": (),
                                "line": node.lineno}
                            self.jit_bindings[node.name] = st
            if not isinstance(node, ast.Call):
                continue
            kind = self._classify(node.func)
            if kind is None or not node.args:
                continue
            self._mark(node.args[0], kind)
            d = dotted(node.func)
            if kind == "trace" and (d in _JIT_NAMES
                                    or self._canon(d) in _JIT_NAMES):
                for name in assigned_names.get(id(node), []):
                    self.jit_bindings[name] = self._static_info(node)

    # -- transitive closure ----------------------------------------------

    def _close_transitively(self) -> None:
        """A def called (by resolvable name) from a traced body is traced
        too — "transitively, within a module". Kernel kind propagates as
        kernel (a helper inlined into a kernel body obeys kernel rules)."""
        work = [f for f in self._funcs if f.kind]
        while work:
            src = work.pop()
            for node in ast.walk(src.node):
                if not isinstance(node, ast.Call):
                    continue
                callee = None
                if isinstance(node.func, ast.Name):
                    callee = node.func.id
                elif isinstance(node.func, ast.Attribute) and isinstance(
                        node.func.value, ast.Name) and \
                        node.func.value.id == "self":
                    callee = node.func.attr
                if callee is None:
                    continue
                for info in self._by_name.get(callee, []):
                    if info.kind is None:
                        info.kind = src.kind
                        work.append(info)

    # -- public queries ---------------------------------------------------

    def trace_roots(self) -> List[Tuple[ast.AST, str]]:
        """Outermost traced/kernel bodies: (def-or-lambda node, kind).
        Nested traced defs are reachable by walking the root's subtree,
        so rules visit each traced line exactly once."""
        roots: List[Tuple[ast.AST, str]] = []
        for f in self._funcs:
            if f.kind and not any(a.kind for a in f.ancestors()):
                roots.append((f.node, f.kind))
        root_nodes = [n for n, _ in roots]
        for lam, kind in self._traced_lambdas.items():
            if not any(lam in ast.walk(r) for r in root_nodes):
                roots.append((lam, kind))
        return roots

    def traced_nodes(self) -> Set[int]:
        """ids of every AST node inside any traced/kernel body — the
        host-side rules use this to scope themselves OUT of traces."""
        out: Set[int] = set()
        for root, _ in self.trace_roots():
            for node in ast.walk(root):
                out.add(id(node))
        return out

    # -- array-valued name inference --------------------------------------

    def array_names(self, func: ast.AST) -> Set[str]:
        """Names in ``func``'s body that (heuristically) hold traced
        arrays: assigned from jnp./lax./jax.lax-rooted calls (minus the
        static helpers), or derived from an already-tracked name. Function
        parameters are deliberately NOT assumed to be arrays — traced
        closures routinely take static config operands, and flagging
        ``if cfg_flag:`` would bury the real findings."""
        tracked: Set[str] = set()
        for _ in range(8):  # fixpoint; depth-8 chains are beyond real code
            grew = False
            for node in ast.walk(func):
                if isinstance(node, (ast.Assign, ast.AnnAssign,
                                     ast.AugAssign)):
                    value = node.value
                    if value is None or not self.is_array_expr(
                            value, tracked):
                        continue
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for tgt in targets:
                        names = [tgt] if isinstance(tgt, ast.Name) else (
                            tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                            else [])
                        for el in names:
                            if isinstance(el, ast.Name) \
                                    and el.id not in tracked:
                                tracked.add(el.id)
                                grew = True
            if not grew:
                break
        return tracked

    def is_array_expr(self, expr: ast.AST, tracked: Set[str]) -> bool:
        """Does ``expr`` (heuristically) evaluate to a traced array?"""
        if isinstance(expr, ast.Name):
            return expr.id in tracked
        if isinstance(expr, ast.Call):
            d = dotted(expr.func)
            if d:
                parts = d.split(".")
                if parts[0] == "jnp" and parts[-1] \
                        not in STATIC_JNP_HELPERS:
                    return True
                if parts[0] == "lax":
                    return True
                if parts[0] == "jax" and len(parts) > 1 and parts[1] in (
                        "lax", "nn", "random"):
                    return True
                if parts[0] in tracked:       # x.astype(...), x.at[..]...
                    return True
            return False
        if isinstance(expr, ast.BinOp):
            return (self.is_array_expr(expr.left, tracked)
                    or self.is_array_expr(expr.right, tracked))
        if isinstance(expr, ast.UnaryOp):
            return self.is_array_expr(expr.operand, tracked)
        if isinstance(expr, (ast.Subscript, ast.Attribute)):
            return call_root(expr) in tracked
        if isinstance(expr, ast.Compare):
            # ==/!=/< on an array is an array; `is None` etc. is not
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in expr.ops):
                return False
            return (self.is_array_expr(expr.left, tracked)
                    or any(self.is_array_expr(c, tracked)
                           for c in expr.comparators))
        return False
