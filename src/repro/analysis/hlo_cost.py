"""Trip-count-aware cost accounting over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — for a
scanned-layer transformer that under-counts FLOPs/bytes/collectives by ~L x.
This module parses the post-SPMD HLO text, extracts per-while trip counts
from ``backend_config={"known_trip_count":{"n":...}}``, and walks the call
graph from ENTRY multiplying through loop nests.

Accounting model (per device, since SPMD HLO is the per-device program):
  * flops: exact for `dot` (2 * numel(out) * prod(contracting dims)),
    numel(out) for elementwise arithmetic (incl. inside fusions);
  * bytes: operand + output bytes of *materialization-level* ops — fusion
    internals are free (they model registers/VMEM residency), parameters /
    tuples / GTEs / bitcasts are free;
  * collective bytes: operand bytes per collective kind, trip-multiplied.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s1": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e\d+m\d+(?:fn)?)?)\[([\d,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id", "rng",
             "rng-bit-generator"}

_ELTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder",
}
_TRANSCEND_OPS = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                  "logistic", "sine", "cosine", "atan2", "erf",
                  "exponential-minus-one", "log-plus-one", "cbrt"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over all array components in a type string."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    # name -> result type string (includes computation parameters)
    symbols: Dict[str, str]
    params: List[str] = dataclasses.field(default_factory=list)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPCODE = re.compile(r"\s*([a-z][a-z0-9\-]*)\(")


def _match_instr(line: str):
    """(name, type_str, opcode, rest_after_open_paren) or None. Handles tuple
    types with embedded /*index=N*/ comments via balanced-paren scanning."""
    m = _INSTR_HEAD.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        tstr, rest = rest[: i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        tstr, rest = rest[:sp], rest[sp:]
    m2 = _OPCODE.match(rest)
    if not m2:
        return None
    return name, tstr, m2.group(1), rest[m2.end():]
_TRIP = re.compile(r'known_trip_count\W+n\W+(\d+)')
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_B = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                # parameters: "name: type, name2: type2" (types may be tuples)
                params = m.group(3)
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^()]*\)|[^,()]+(?:\([^()]*\))?)+)",
                                      params):
                    cur.symbols[pm.group(1)] = pm.group(2)
                    cur.params.append(pm.group(1))
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _match_instr(line)
        if not m:
            continue
        name, tstr, opcode, rest = m
        # operands: text up to the matching close paren — take up to "), "
        depth = 1
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnd_text = rest[:end]
        attrs = rest[end + 1:]
        operands = _OPERAND_NAME.findall(opnd_text)
        instr = Instr(name, tstr, opcode, operands, attrs)
        cur.instrs.append(instr)
        cur.symbols[name] = tstr
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops: float = 0.0
    transcendentals: float = 0.0
    # diagnostics: bytes per opcode and the largest single contributors
    by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    top: List[Tuple[float, str, str]] = dataclasses.field(
        default_factory=list)

    @property
    def total_coll(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def _note(self, op: str, b: float, detail: str = "") -> None:
        self.by_op[op] = self.by_op.get(op, 0.0) + b
        if b > 1e8:
            self.top.append((b, op, detail[:120]))
            if len(self.top) > 400:
                self.top.sort(reverse=True)
                del self.top[200:]

    def top_entries(self, n: int = 15):
        return sorted(self.top, reverse=True)[:n]


def _operand_bytes(comp: Computation, instr: Instr,
                   comps: Dict[str, Computation]) -> int:
    total = 0
    for op in instr.operands:
        t = comp.symbols.get(op)
        if t is None:
            continue
        total += _shape_elems_bytes(t)[1]
    return total


_SLICING = {"dynamic-slice", "gather"}


def _fusion_io_bytes(comp: Computation, instr: Instr,
                     comps: Dict[str, Computation]) -> float:
    """Traffic for a fusion call: output + inputs, where inputs consumed only
    through dynamic-slice/gather inside the fused computation are charged at
    slice size (models scanned weight stacks correctly), and a root
    dynamic-update-slice aliases its target buffer (in-place cache update)."""
    called = comps.get((_CALLS.search(instr.attrs) or [None]).group(1)
                       if _CALLS.search(instr.attrs) else None)
    out_bytes = _shape_elems_bytes(instr.type_str)[1]
    if called is None or len(called.params) != len(instr.operands):
        return out_bytes + _operand_bytes(comp, instr, comps)
    defs = {i.name: i for i in called.instrs}
    _TRIVIAL = {"convert", "copy", "bitcast", "reshape", "transpose",
                "broadcast"}

    def trace_param(name: str):
        seen = 0
        while name in defs and defs[name].opcode in _TRIVIAL and seen < 8:
            if not defs[name].operands:
                break
            name = defs[name].operands[0]
            seen += 1
        return name if name in called.params else None

    sliced = {}          # param name -> slice bytes to charge instead
    aliased = set()      # param names written in place (charge 0 read)
    root_dus_update = None
    for ins in called.instrs:
        if ins.opcode in _SLICING and ins.operands:
            pn = trace_param(ins.operands[0])
            if pn is not None:
                sliced[pn] = sliced.get(pn, 0) + \
                    _shape_elems_bytes(ins.type_str)[1]
        if ins.opcode == "dynamic-update-slice" and ins.operands:
            pn = trace_param(ins.operands[0])
            if pn is not None:
                aliased.add(pn)
                if len(ins.operands) > 1:
                    ut = called.symbols.get(ins.operands[1])
                    if ut:
                        root_dus_update = _shape_elems_bytes(ut)[1]
    total = 0.0
    for pn, on in zip(called.params, instr.operands):
        t = comp.symbols.get(on)
        full = _shape_elems_bytes(t)[1] if t else 0
        if pn in aliased:
            continue
        total += min(sliced.get(pn, full), full) if pn in sliced else full
    if root_dus_update is not None:
        total += 2.0 * root_dus_update  # read-modify-write of the window
        # output aliases the big buffer: do not charge the full write
        return total
    return total + out_bytes


def _dot_flops(comp: Computation, instr: Instr) -> float:
    out_elems, _ = _shape_elems_bytes(instr.type_str)
    lhs_t = comp.symbols.get(instr.operands[0]) if instr.operands else None
    if lhs_t is None:
        return 0.0
    m = _SHAPE_RE.search(lhs_t)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",") if d]
    cm = _LHS_C.search(instr.attrs)
    contract = [int(d) for d in cm.group(1).split(",") if d] if cm else []
    k = 1
    for d in contract:
        if d < len(dims):
            k *= dims[d]
    return 2.0 * out_elems * k


def _flops_only(comp: Computation, comps, mult: float, cost: Cost,
                seen: set) -> None:
    """Count flops inside fusion computations (bytes stay at the boundary)."""
    for ins in comp.instrs:
        if ins.opcode == "dot":
            f = _dot_flops(comp, ins) * mult
            cost.flops += f
            cost.dot_flops += f
        elif ins.opcode in _ELTWISE_FLOP_OPS:
            cost.flops += _shape_elems_bytes(ins.type_str)[0] * mult
        elif ins.opcode in _TRANSCEND_OPS:
            n = _shape_elems_bytes(ins.type_str)[0] * mult
            cost.flops += n
            cost.transcendentals += n
        cm = _CALLS.search(ins.attrs)
        if cm and cm.group(1) in comps and cm.group(1) not in seen:
            _flops_only(comps[cm.group(1)], comps, mult, cost,
                        seen | {comp.name})


def _walk(comp: Computation, comps: Dict[str, Computation], mult: float,
          cost: Cost) -> None:
    for ins in comp.instrs:
        op = ins.opcode
        if op in _FREE_OPS:
            continue
        if op == "while":
            tm = _TRIP.search(ins.attrs)
            trips = int(tm.group(1)) if tm else 1
            bm = _CALLS.search(ins.attrs)
            if bm and bm.group(1) in comps:
                _walk(comps[bm.group(1)], comps, mult * trips, cost)
            continue
        if op in ("call", "conditional", "async-start"):
            for cm in _CALLS.finditer(ins.attrs):
                if cm.group(1) in comps:
                    _walk(comps[cm.group(1)], comps, mult, cost)
            continue
        coll = next((c for c in COLLECTIVES if op.startswith(c)), None)
        if coll is not None:
            if op.endswith("-done"):
                continue
            b = _operand_bytes(comp, ins, comps) * mult
            cost.coll_bytes[coll] = cost.coll_bytes.get(coll, 0.0) + b
            tot = b + _shape_elems_bytes(ins.type_str)[1] * mult
            cost.bytes += tot
            cost._note(op, tot, ins.name)
            continue
        # materialization-level op: operands + outputs traffic
        if op == "fusion":
            fb = _fusion_io_bytes(comp, ins, comps) * mult
            cost.bytes += fb
            cost._note(op, fb, ins.name)
            cm = _CALLS.search(ins.attrs)
            if cm and cm.group(1) in comps:
                _flops_only(comps[cm.group(1)], comps, mult, cost, set())
            continue
        if op in _SLICING:
            b = 2.0 * _shape_elems_bytes(ins.type_str)[1] * mult
            cost.bytes += b
            cost._note(op, b, ins.name)
            continue
        if op == "dynamic-update-slice":
            ut = comp.symbols.get(ins.operands[1]) if len(ins.operands) > 1 \
                else None
            upd = _shape_elems_bytes(ut)[1] if ut else 0
            cost.bytes += 2.0 * upd * mult
            cost._note(op, 2.0 * upd * mult, ins.name)
            continue
        b = (_operand_bytes(comp, ins, comps)
             + _shape_elems_bytes(ins.type_str)[1]) * mult
        cost.bytes += b
        cost._note(op, b, ins.name)
        if op == "dot":
            f = _dot_flops(comp, ins) * mult
            cost.flops += f
            cost.dot_flops += f
        elif op in _ELTWISE_FLOP_OPS:
            cost.flops += _shape_elems_bytes(ins.type_str)[0] * mult
        elif op in _TRANSCEND_OPS:
            n = _shape_elems_bytes(ins.type_str)[0] * mult
            cost.flops += n
            cost.transcendentals += n


def analyze_hlo(hlo_text: str) -> Cost:
    comps = parse_computations(hlo_text)
    entry = next((c for n, c in comps.items() if "main" in n), None)
    if entry is None:  # fall back: the last computation is usually ENTRY
        entry = list(comps.values())[-1]
    cost = Cost()
    _walk(entry, comps, 1.0, cost)
    return cost
