"""Three-term roofline analysis from a compiled dry-run artifact.

  compute    = HLO_FLOPs        / (chips * PEAK_FLOPS)
  memory     = HLO_bytes        / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis: we parse the post-SPMD optimized HLO (``compiled.as_text()``)
and sum the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.

Hardware constants (TPU v5e-class, per assignment): 197 TFLOP/s bf16 per
chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link per chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)")
_SHAPE_RE = re.compile(r"(pred|[su]\d+|[bf]f?\d+(?:e\dm\d(?:fn)?)?)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind, operands = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(operands):
            total += _shape_bytes(sm.group(1), sm.group(2))
        out[kind] = out.get(kind, 0) + total
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO FLOPs
    bytes_hbm: float           # per-device HLO bytes accessed
    bytes_coll: float          # per-device collective operand bytes
    chips: int
    coll_breakdown: Dict[str, int]
    model_flops: float = 0.0   # analytic 6*N*D (global)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_coll / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): compiled-compute usefulness."""
        total_hlo = self.flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline achieved if the step runs at the
        bound of its dominant term: t_compute_model / t_bound."""
        t_model = self.model_flops / (self.chips * PEAK_FLOPS)
        return t_model / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.bytes_hbm,
            "collective_bytes_per_device": self.bytes_coll,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "coll_breakdown": self.coll_breakdown,
        }


def analyze(compiled, *, chips: int, model_flops: float = 0.0,
            hlo_text: Optional[str] = None) -> Roofline:
    """Trip-count-aware analysis via hlo_cost (XLA's own cost_analysis counts
    while bodies once, so it is only kept as a cross-reference)."""
    from repro.analysis import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    cost = hlo_cost.analyze_hlo(text)
    return Roofline(
        flops=cost.flops,
        bytes_hbm=cost.bytes,
        bytes_coll=cost.total_coll,
        chips=chips,
        coll_breakdown={k: int(v) for k, v in cost.coll_bytes.items()},
        model_flops=model_flops,
    )


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D (dense) / 6*N_active*D (MoE); decode
    processes global_batch tokens (one step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: 1 new token per sample
