"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def load_cells(dryrun_dir: str = DRYRUN_DIR, mesh: Optional[str] = None,
               tag: str = "") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        cell_tag = rec.get("cell", "").split("__")[3:]
        if (cell_tag[0] if cell_tag else "") != tag:
            continue
        rows.append(rec)
    return rows


def fmt_bytes(n: float) -> str:
    return f"{n / 2**30:.2f}"


def roofline_table(rows: List[Dict]) -> str:
    """Markdown table: one row per ok cell."""
    hdr = ("| arch | shape | mesh | mem/dev GiB | t_comp s | t_mem s | "
           "t_coll s | bottleneck | useful_flops | roofline_frac |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['cell'].split('__')[0]} | "
                       f"{r['cell'].split('__')[1]} | "
                       f"{r['cell'].split('__')[2]} | — | — | — | — | "
                       f"SKIP ({r['reason'][:40]}…) | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r.get('arch')} | {r.get('shape')} | "
                       f"{r.get('mesh')} | — | — | — | — | "
                       f"ERROR {r.get('error', '')[:40]} | — | — |")
            continue
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_bytes(r['memory']['total_bytes_per_device'])} | "
            f"{rl['t_compute_s']:.4f} | {rl['t_memory_s']:.4f} | "
            f"{rl['t_collective_s']:.4f} | {rl['bottleneck']} | "
            f"{rl['useful_flops_frac']:.3f} | {rl['roofline_frac']:.3f} |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> Dict:
    ok = [r for r in rows if r.get("status") == "ok"]
    skip = [r for r in rows if r.get("status") == "skipped"]
    err = [r for r in rows if r.get("status") == "error"]
    bn = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        bn[b] = bn.get(b, 0) + 1
    return {"ok": len(ok), "skipped": len(skip), "error": len(err),
            "bottlenecks": bn}


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else None
    rows = load_cells(mesh=mesh)
    print(roofline_table(rows))
    print()
    print(summary(rows))
