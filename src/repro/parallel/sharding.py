"""Logical-axis sharding rules with divisibility fallbacks.

Models annotate tensors with *logical* axis names (comma-separated strings,
one name per dim, ``""``/missing = replicated). ``Rules`` maps them onto the
physical mesh, falling back to replication when a dim is not divisible by
the mapped mesh-axis size (e.g. llama4's 40 heads on a 16-way ``model``
axis) — the standard production-framework behaviour, but LOUD: the first
fallback per (instance, logical axis) emits a ``warnings.warn`` naming the
axis, so a config silently serving replicated where the operator asked for
sharded is visible (ISSUE 6 satellite).

Weight FSDP axes use the dedicated name ``wembed``/``wff`` so that weight
sharding (over ``pod``+``data``) never collides with activation sharding.

``ManualRules`` is the in-``shard_map`` variant: inside a manual-mode body
arrays are per-device blocks, so ``cons`` (a GSPMD hint) is meaningless and
becomes identity, while contractions over a sharded logical axis need an
explicit ``psum`` — that is ``contract``, identity on the base class.
"""
from __future__ import annotations

import warnings
from typing import Dict, FrozenSet, Iterable, Optional, Tuple, Union

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

Phys = Union[str, Tuple[str, ...], None]

# logical axis -> mesh axis (tuples compose axes)
DEFAULT_TABLE: Dict[str, Phys] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",          # sequence parallelism (opt-in)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "state": None,
    # weights (FSDP axis)
    "wembed": ("pod", "data"),
    "wff": "model",             # tensor-parallel weight dim
    "wvocab": "model",
    "wheads": "model",
    "wkv_heads": "model",
    "wexperts": "model",
    "layers": None,
}


class Rules:
    """Maps logical-axes strings to PartitionSpecs for a concrete mesh."""

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None,
                 table: Optional[Dict[str, Phys]] = None):
        self.mesh = mesh
        self.table = dict(DEFAULT_TABLE)
        if table:
            self.table.update(table)
        self._warned_axes: set = set()   # one fallback warning per axis

    # -- helpers ----------------------------------------------------------
    def _axis_size(self, phys: Phys) -> int:
        if self.mesh is None or phys is None:
            return 1
        names = phys if isinstance(phys, tuple) else (phys,)
        return int(np.prod([self.mesh.shape[a] for a in names]))

    def spec(self, shape: Tuple[int, ...], axes: str) -> P:
        """PartitionSpec for `shape` given comma-separated logical names."""
        if self.mesh is None:
            return P()
        names = [a.strip() for a in axes.split(",")] if axes else []
        names += [""] * (len(shape) - len(names))
        out, used = [], set()
        for dim, name in zip(shape, names):
            phys = self.table.get(name)
            if phys is None:
                out.append(None)
                continue
            pt = tuple(a for a in (phys if isinstance(phys, tuple)
                                   else (phys,))
                       if a in self.mesh.shape)    # drop absent axes (pod)
            if not pt or any(a in used for a in pt):
                out.append(None)            # absent-axis / conflict fallback
                continue
            if dim % self._axis_size(pt) != 0:
                # divisibility fallback: replicate, but say so ONCE per
                # (instance, logical axis) — a 16-way mesh quietly serving
                # llama4's 40 heads replicated is exactly the surprise an
                # operator wants named (ISSUE 6 satellite)
                if name not in self._warned_axes:
                    self._warned_axes.add(name)
                    warnings.warn(
                        f"logical axis {name!r} (dim {dim}) is not "
                        f"divisible by mesh axis {'x'.join(pt)} (size "
                        f"{self._axis_size(pt)}); replicating this dim "
                        f"instead of sharding it", stacklevel=3)
                out.append(None)
                continue
            out.append(pt if len(pt) > 1 else pt[0])
            used.update(pt)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, shape: Tuple[int, ...], axes: str) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def cons(self, x, axes: str):
        """with_sharding_constraint when a mesh is active; identity otherwise."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(x.shape, axes)))

    def contract(self, x, axis: str):
        """Hook at a contraction over logical ``axis`` (e.g. the attention
        out-projection contracts "heads", the MLP down-projection "ffn").
        Identity under GSPMD auto-partitioning — the partitioner inserts
        the reduction itself; ``ManualRules`` overrides with an explicit
        psum for shard_map bodies."""
        return x

    def tree_specs(self, shapes_tree, axes_tree):
        """PartitionSpec pytree from a ShapeDtypeStruct tree + axes-str tree."""
        return jax.tree.map(lambda s, a: self.spec(s.shape, a),
                            shapes_tree, axes_tree)

    def tree_shardings(self, shapes_tree, axes_tree):
        assert self.mesh is not None
        return jax.tree.map(
            lambda s, a: NamedSharding(self.mesh, self.spec(s.shape, a)),
            shapes_tree, axes_tree)


class ManualRules(Rules):
    """Rules for use INSIDE a ``shard_map`` body (manual mode).

    Per-device blocks mean ``cons`` must be identity and ``spec`` sees no
    mesh (both inherited by constructing the base with ``mesh=None``);
    what manual mode DOES need is an explicit all-reduce wherever the
    model contracts over a logical axis that is physically sharded —
    ``contract`` psums over ``axis_name`` for exactly the axes in
    ``contract_axes`` and is identity for the rest (an axis that fell
    back to replication must NOT be reduced, or the output is multiplied
    by the shard count)."""

    def __init__(self, contract_axes: Iterable[str] = (),
                 axis_name: str = "model"):
        super().__init__(None)
        self.contract_axes: FrozenSet[str] = frozenset(contract_axes)
        self.axis_name = axis_name

    def contract(self, x, axis: str):
        if axis in self.contract_axes:
            return jax.lax.psum(x, self.axis_name)
        return x


NO_RULES = Rules(None)
