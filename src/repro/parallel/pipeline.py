"""GPipe-style pipeline parallelism over shard_map + ppermute.

Stages are laid out along a mesh axis; each device executes its stage's
params on a stream of microbatches and hands activations to the next
stage with ``lax.ppermute`` over a ring. Classic GPipe schedule: with M
microbatches and S stages, the loop runs M + S - 1 ticks and the bubble
fraction is (S-1)/(M+S-1). Bubble ticks execute the stage on don't-care
data (exactly what the hardware would do) — only valid outputs are
collected at the last stage.

This is the optional pipeline mode of the launcher (maps stages to the
"pod" axis in the multi-pod mesh); the dry-run proves it lowers and
compiles, tests/test_pipeline.py proves numerical equivalence to the
sequential stack on a forced-multi-device CPU.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn: Callable, mesh: jax.sharding.Mesh, axis: str):
    """Build a pipelined apply: (stacked_params, microbatches) -> outputs.

    stage_fn(params_slice, x) -> y must be shape-preserving in x (the
    usual transformer-block contract).
    stacked_params: pytree with leading dim = n_stages on every leaf.
    microbatches:   (n_micro, mb, ...) array (already microbatched).
    """
    n_stages = mesh.shape[axis]

    def per_stage(params, xs):
        # params: this stage's slice (leading dim 1); xs: full microbatch
        # stream, meaningful at stage 0 only (replicated over the axis).
        p = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        T = n_micro + n_stages - 1
        ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            state, buf = carry
            inject = xs[jnp.clip(t, 0, n_micro - 1)]
            x_in = jnp.where(idx == 0, inject, state)
            y = stage_fn(p, x_in)
            nxt = jax.lax.ppermute(y, axis, ring)
            out_t = t - (n_stages - 1)
            valid = (idx == n_stages - 1) & (out_t >= 0)
            upd = jax.lax.dynamic_update_index_in_dim(
                buf, y, jnp.clip(out_t, 0, n_micro - 1), 0)
            buf = jnp.where(valid, upd, buf)
            return (nxt, buf), None

        init = (jnp.zeros_like(xs[0]), jnp.zeros_like(xs))
        (_, buf), _ = jax.lax.scan(tick, init, jnp.arange(T))
        # only the last stage holds real outputs; broadcast them back
        # (psum of the masked buffer over the ring)
        buf = jnp.where(idx == n_stages - 1, buf, jnp.zeros_like(buf))
        return jax.lax.psum(buf, axis)

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),       # params sharded by stage; xs replicated
        out_specs=P(),
        check_rep=False)


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
