"""Tensor-parallel plan for the paged serving engine.

One ``TPPlan`` per (config, mesh) answers three questions the sharded
engine needs settled BEFORE tracing anything:

* **What shards.** KV heads are the shard axis (the paper's banked shared
  memory mapped to devices: each bank/shard owns its GQA group's pages and
  every page access stays shard-local). Attention weights shard over
  heads/kv_heads — but only when BOTH divide the ``model`` axis size: head
  j reads kv head ``j // G`` (kv-major), so sharding q-heads while
  replicating kv-heads would break the grouping inside a shard. MLP
  weights shard over ``ffn`` unless the config carries MoE (the MoE block
  stays replicated, and its always-on shared expert runs through
  ``mlp_apply`` whose unconditional ``contract("ffn")`` would then psum an
  already-full output). Everything else — embeddings, norms, recurrent
  mixers, MoE, block tables, positions, recurrent state slots — is
  replicated; a non-divisible axis falls back to replication with a loud
  warning (``parallel/sharding.py``) instead of crashing the engine.
* **Which specs.** Param specs come from the same logical-axes tree the
  models already emit (``api.param_axes``), restricted to the ``attn`` /
  ``mlp`` param subtrees; cache specs from ``api.paged_cache_axes`` (page
  pools shard dim 2 — KV heads — state slots replicate). Both are plain
  ``PartitionSpec`` trees, usable as ``shard_map`` in/out specs and (via
  ``NamedSharding``) as ``device_put`` targets.
* **Where the psums go.** ``plan.rules`` is a ``ManualRules`` whose
  ``contract`` psums over ``"model"`` for exactly the axes that actually
  sharded — the attention out-projection ("heads") and the MLP
  down-projection ("ffn") are the only two contraction points, and the
  online-softmax state inside each shard's flash-decode never crosses
  shards (GQA groups are self-contained).

The engine then wraps each traced program's model call in ONE
``compat.shard_map`` boundary (``plan.shard``), so the
one-host-sync-per-step contract survives sharding unchanged.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, FrozenSet, Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.parallel.sharding import DEFAULT_TABLE, ManualRules, Rules

# logical axes that may shard over "model" under the serving TP plan, and
# the contraction axis each group funds (None = pure weight-dim sharding)
_ATTN_AXES = ("heads", "kv_heads", "wheads", "wkv_heads")
_FFN_AXES = ("ffn", "wff")
# param subtrees whose weights participate in TP; everything outside
# (embed/head/norms, "mixer", "moe", "cross") is replicated — mixers have
# no contract() hook and MoE dispatch needs its full expert dim
_SHARDED_SUBTREES = ("attn", "mlp")


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """Frozen answers: mesh + which logical axes actually sharded."""
    mesh: jax.sharding.Mesh
    model_shards: int
    sharded_axes: FrozenSet[str]
    rules: ManualRules                 # for INSIDE shard_map bodies

    # -- spec construction -------------------------------------------------
    def _spec_rules(self) -> Rules:
        table = {name: ("model" if name in self.sharded_axes else None)
                 for name in DEFAULT_TABLE}
        return Rules(self.mesh, table)

    def param_specs(self, cfg) -> Any:
        """PartitionSpec tree matching ``api.init_params(cfg, ...)``: attn
        and mlp weights shard per ``sharded_axes``, everything else P()."""
        from repro.models import api
        rules = self._spec_rules()
        shapes = api.param_shapes(cfg)
        axes = api.param_axes(cfg)

        def spec(path, shape_leaf, axes_leaf):
            keys = [str(getattr(k, "key", getattr(k, "name", "")))
                    for k in path]
            if not any(k in _SHARDED_SUBTREES for k in keys):
                return P()
            return rules.spec(shape_leaf.shape, axes_leaf)

        return jax.tree_util.tree_map_with_path(spec, shapes, axes)

    def cache_specs(self, cfg, cache) -> Any:
        """PartitionSpec tree for a concrete paged cache tree: page pools
        shard their KV-heads dim, recurrent state slots replicate."""
        from repro.models import api
        rules = self._spec_rules()
        axes = api.paged_cache_axes(cfg)
        return jax.tree.map(lambda leaf, a: rules.spec(leaf.shape, a),
                            cache, axes)

    # -- placement / mapping ----------------------------------------------
    def shardings(self, specs) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def put(self, tree, specs) -> Any:
        """device_put `tree` onto the mesh per `specs` (replicated where
        P()) so the first traced program starts from resident shards
        instead of paying a broadcast per call."""
        return jax.device_put(tree, self.shardings(specs))

    def shard(self, fn, in_specs, out_specs):
        """The one manual boundary per traced program."""
        return _shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                          out_specs=out_specs)


def tp_plan(cfg, mesh: Optional[jax.sharding.Mesh]) -> Optional[TPPlan]:
    """Decide what shards for ``cfg`` on ``mesh`` (None mesh -> None plan).

    The divisibility coupling lives here, not per-leaf: heads and kv_heads
    shard together or not at all (GQA alignment), ffn sharding is disabled
    outright for MoE-bearing configs. Either fallback warns once, naming
    the axis — the engine keeps serving, replicated."""
    if mesh is None:
        return None
    if "model" not in mesh.shape:
        raise ValueError(
            f"tp_plan needs a mesh with a 'model' axis; got axes "
            f"{tuple(mesh.shape)}")
    m = int(mesh.shape["model"])
    sharded: set = set()
    if m > 1:
        if cfg.num_heads % m == 0 and cfg.kv_heads % m == 0:
            sharded.update(_ATTN_AXES)
        else:
            warnings.warn(
                f"{cfg.name}: heads={cfg.num_heads}/kv_heads="
                f"{cfg.kv_heads} do not both divide model={m}; attention "
                f"(weights AND kv page pools) replicates per shard",
                stacklevel=2)
        has_moe = cfg.moe is not None and cfg.moe.num_experts > 0
        if has_moe:
            pass                       # MoE block replicates; see module doc
        elif cfg.d_ff % m == 0:
            sharded.update(_FFN_AXES)
        else:
            warnings.warn(
                f"{cfg.name}: d_ff={cfg.d_ff} does not divide model={m}; "
                f"MLP weights replicate per shard", stacklevel=2)
    contract = {a for a in ("heads", "ffn") if a in sharded}
    return TPPlan(mesh=mesh, model_shards=m,
                  sharded_axes=frozenset(sharded),
                  rules=ManualRules(contract, axis_name="model"))
