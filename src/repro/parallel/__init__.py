from repro.parallel.sharding import Rules, NO_RULES  # noqa: F401
