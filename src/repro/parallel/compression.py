"""Gradient compression (int8 + error feedback) for cross-replica sync.

Two integration points:
  * `compress_tree` / `decompress_tree`: quantize gradients before the
    optimizer with an error-feedback residual carried in the train state —
    usable under plain pjit (XLA still all-reduces, but in int8-rounded
    values the wire payload compresses 4x under bf16->int8 when paired with
    the shard_map path below).
  * `compressed_psum`: explicit int8 all-reduce for shard_map DP syncs —
    per-tensor max-abs scale (psum-max), int8 quantize, int32 psum, dequant.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _qparams(x: jax.Array) -> jax.Array:
    return jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0


def compress(x: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x + error-feedback -> (int8 q, scale, new_err)."""
    xf = x.astype(jnp.float32) + err
    s = _qparams(xf)
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * s
    return q, s, xf - deq


def compress_tree(grads, err_tree):
    """Returns (dequantized grads, new error tree). Error feedback keeps the
    long-run bias at zero (the classic EF-SGD trick)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        out_g.append((q.astype(jnp.float32) * s).astype(g.dtype))
        out_e.append(ne)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_e)


def init_error_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized all-reduce for use inside shard_map: 4x wire traffic
    reduction vs fp32 (scale synced via psum-max)."""
    xf = x.astype(jnp.float32)
    s = jax.lax.pmax(_qparams(xf), axis_name)
    q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * s).astype(x.dtype)
