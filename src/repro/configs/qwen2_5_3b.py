"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936, GQA + QKV bias. [hf:Qwen/Qwen2.5-3B]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, kv_heads=2,
    d_ff=11008, vocab=151936, head_dim=128, qkv_bias=True,
    norm="rmsnorm", act="silu", gated_ffn=True, rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2.5-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=128, vocab=256)
