"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 (padded to 49408 for TP), GQA, tied embeddings.
[hf:ibm-granite/granite-3.0-2b-base]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    num_layers=40, d_model=2048, num_heads=32, kv_heads=8,
    d_ff=8192, vocab=49155, head_dim=64, tie_embeddings=True,
    norm="rmsnorm", act="silu", gated_ffn=True, rope_theta=10_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="granite-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=128, vocab=250)
