"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
(InternLM2-1.8B backbone). The InternViT frontend is a STUB: ``input_specs``
provides 256 precomputed patch embeddings (post pixel-shuffle + MLP projector)
prepended to the token stream. [arXiv:2404.16821]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, kv_heads=8,
    d_ff=8192, vocab=92553, head_dim=128,
    norm="rmsnorm", act="silu", gated_ffn=True, rope_theta=1_000_000.0,
    frontend="patch", frontend_tokens=256,
)

SMOKE = dataclasses.replace(
    CONFIG, name="internvl2-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=128, vocab=256, frontend_tokens=8)
