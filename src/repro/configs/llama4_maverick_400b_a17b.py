"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + shared expert, MoE every 2nd layer
(interleaved, matching the published 400B-total / 17B-active design — see
DESIGN.md for the interpretation of the one-line spec).
[hf:meta-llama/Llama-4-Maverick-17B-128E]"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    norm="rmsnorm", act="silu", gated_ffn=True, rope_theta=500_000.0,
    moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25,
                  moe_every=2, shared_expert=True),
    moment_dtype="bfloat16",   # 400B params: fp32 moments exceed 16 GB/chip
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama4-smoke", num_layers=4, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=96, vocab=256,
    moe=MoEConfig(num_experts=8, top_k=1, capacity_factor=1.5,
                  moe_every=2, shared_expert=True))
