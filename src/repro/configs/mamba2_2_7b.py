"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free, ssm_state=128,
SSD (state-space duality). head_dim=64, expand=2 -> d_inner=5120, 80 heads.
[arXiv:2405.21060]"""
import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=80, kv_heads=0,
    d_ff=0, vocab=50280, head_dim=64,
    norm="rmsnorm",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256, num_groups=1),
)

SMOKE = dataclasses.replace(
    CONFIG, name="mamba2-smoke", num_layers=2, d_model=64, num_heads=4,
    vocab=256,
    ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, conv_width=4,
                  chunk=8, num_groups=1))
