"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936, QKV bias. [hf:Qwen/Qwen1.5-4B]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    num_layers=40, d_model=2560, num_heads=20, kv_heads=20,
    d_ff=6912, vocab=151936, head_dim=128, qkv_bias=True,
    norm="rmsnorm", act="silu", gated_ffn=True, rope_theta=5_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen1.5-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=4, head_dim=16, d_ff=128, vocab=256)
