"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4 (fine-grained). [hf:databricks/dbrx-base]"""
import dataclasses

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128,
    norm="layernorm", act="silu", gated_ffn=True, rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, capacity_factor=1.25),
    moment_dtype="float32",
)

SMOKE = dataclasses.replace(
    CONFIG, name="dbrx-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=96, vocab=256,
    moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=1.5))
