"""Configuration system: model/shape/mesh/run configs + input_specs().

Every assigned architecture gets a ``ModelConfig`` in ``configs/<id>.py``.
Shapes are the four assigned input-shape cells; ``input_specs`` builds
ShapeDtypeStruct stand-ins (no allocation) for dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    moe_every: int = 1          # MoE FFN every Nth layer (1 = every layer)
    shared_expert: bool = False  # extra always-on expert (llama4 style)
    # GShard-style dispatch groups: capacity is enforced per group and the
    # group dim is sharded with the batch, so routing scatters stay local
    # to their data shard (EXPERIMENTS.md §Perf B6). 32 = pod*data of the
    # production mesh; groups fall back to 1 when tokens % groups != 0.
    dispatch_groups: int = 32


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128        # N (dstate)
    head_dim: int = 64          # P  (d_inner = heads * head_dim)
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256            # SSD chunk length
    num_groups: int = 1         # B/C groups (like GQA for SSM)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Griffin/RecurrentGemma style block pattern."""
    pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "local_attn")
    window: int = 2048              # local attention window
    lru_dim: int = 0                # RG-LRU recurrence width (0 = d_model)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | encdec | vlm | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"           # silu (gated) | gelu (plain)
    gated_ffn: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # enc-dec
    enc_layers: int = 0         # >0 -> encoder-decoder
    # vlm / audio frontend stubs
    frontend: Optional[str] = None   # "patch" (vlm) | "frames" (audio)
    frontend_tokens: int = 0         # tokens contributed by the frontend stub
    # numerics / memory policy
    dtype: str = "bfloat16"
    moment_dtype: str = "float32"    # optimizer moment dtype (bf16 for huge MoE)
    remat: str = "full"              # none | full | dots
    # decode attention KV chunk (online softmax over the cache). 0 = single
    # pass — the right choice when the cache seq axis is context-parallel
    # sharded (XLA partitions the einsum; a scan would serialize it).
    decode_kv_chunk: int = 2048
    # chunked (online-softmax scan) vs one-shot full-sequence attention.
    # One-shot is the right path under sequence/context parallelism where
    # the per-device q block is small (EXPERIMENTS.md §Perf A4).
    flash_chunking: bool = True
    # paged-decode attention implementation: "kernel" (Pallas flash-decode,
    # block-table gather inside the kernel — the default and the only path
    # that never materializes the gathered KV) | "gather" (PR-1 baseline:
    # dense pool[block_table] gather per layer, kept as the measured
    # anti-pattern in benchmarks/serve_bench.py). Dense-slot decode ignores
    # this.
    paged_attn_impl: str = "kernel"
    # KV-cache storage dtype: "bfloat16" | "int8". int8 halves decode
    # cache traffic + footprint (the chip's INT8 theme applied to the KV
    # cache); values are stored as round(x * 127 / kv_scale) with a
    # per-model static absmax bound (EXPERIMENTS.md §Perf C4).
    kv_cache_dtype: str = "bfloat16"
    kv_scale: float = 8.0
    # notes (arch-applicability etc.)
    note: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True when the arch supports ~500k-token decode (no full-attn cache)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), used for 6ND."""
        d, dff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.kv_heads * hd + self.num_heads * hd * d
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.kv_heads) * hd
        ffn_dense = (3 if self.gated_ffn else 2) * d * dff
        total = 0
        if self.family == "ssm":
            s = self.ssm
            d_inner = s.expand * d
            per_layer = (
                d * (2 * d_inner + 2 * s.num_groups * s.state_dim + d_inner // s.head_dim)
                + d_inner * s.conv_width
                + d_inner * d
                + d_inner // s.head_dim  # A
            )
            total = self.num_layers * per_layer
        elif self.family == "hybrid":
            h = self.hybrid
            lru = h.lru_dim or self.d_model
            n_attn = sum(1 for b in (h.pattern * self.num_layers)[: self.num_layers] if b == "local_attn")
            n_lru = self.num_layers - n_attn
            # RG-LRU block: in/out proj + gates
            lru_block = 2 * d * lru + 3 * lru * lru // 1  # approx (x,gate projections + recurrent gates)
            total = n_attn * (attn + ffn_dense) + n_lru * (lru_block + ffn_dense)
        else:
            moe = self.moe
            for layer in range(self.num_layers):
                is_moe = moe is not None and moe.num_experts > 0 and (layer % moe.moe_every == moe.moe_every - 1)
                if is_moe:
                    ffn = moe.num_experts * ffn_dense + d * moe.num_experts
                    if moe.shared_expert:
                        ffn += ffn_dense
                else:
                    ffn = ffn_dense
                total += attn + ffn
            if self.is_encdec:
                # encoder layers: self-attn + ffn; decoder layers already counted,
                # add cross-attention to each decoder layer
                total += self.enc_layers * (attn + ffn_dense)
                total += self.num_layers * attn  # cross-attn
        total += v * d * (1 if self.tie_embeddings else 2)
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active params (= total for dense; routed subset for MoE)."""
        if self.moe is None or self.moe.num_experts == 0:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        hd = self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.kv_heads * hd + self.num_heads * hd * d
        ffn_dense = (3 if self.gated_ffn else 2) * d * dff
        moe = self.moe
        total = 0
        for layer in range(self.num_layers):
            is_moe = (layer % moe.moe_every == moe.moe_every - 1)
            if is_moe:
                ffn = moe.top_k * ffn_dense + (ffn_dense if moe.shared_expert else 0)
            else:
                ffn = ffn_dense
            total += attn + ffn
        total += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return int(total)


# ---------------------------------------------------------------------------
# Shapes (assigned cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; reason if not."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; full-attn arch (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins (the dry-run contract)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {tokens, labels[, frontend_embeds]}         -> train_step
    prefill: {tokens[, frontend_embeds]}                  -> prefill_step
    decode:  {tokens(1 new), cache(kv/ssm state), pos}    -> serve_step
    """
    i32 = jnp.int32
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        if cfg.is_encdec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            specs["tokens"] = tok(b, s)
            specs["labels"] = tok(b, s)
        elif cfg.frontend == "patch":
            ft = cfg.frontend_tokens
            specs["frontend_embeds"] = jax.ShapeDtypeStruct((b, ft, cfg.d_model), dt)
            specs["tokens"] = tok(b, s - ft)
            specs["labels"] = tok(b, s - ft)
        else:
            specs["tokens"] = tok(b, s)
            specs["labels"] = tok(b, s)
    elif shape.kind == "prefill":
        if cfg.is_encdec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
            specs["tokens"] = tok(b, s)
        elif cfg.frontend == "patch":
            ft = cfg.frontend_tokens
            specs["frontend_embeds"] = jax.ShapeDtypeStruct((b, ft, cfg.d_model), dt)
            specs["tokens"] = tok(b, s - ft)
        else:
            specs["tokens"] = tok(b, s)
    else:  # decode: one new token against a cache of seq_len
        specs["tokens"] = tok(b, 1)
        specs["pos"] = jax.ShapeDtypeStruct((b,), i32)
        specs["cache"] = cache_specs(cfg, b, s)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Any:
    """Decode-cache ShapeDtypeStructs (KV cache / SSM state / hybrid mix)."""
    from repro.models import api  # local import to avoid cycles
    return api.cache_shapes(cfg, batch, seq_len)
