"""Architecture registry: ``get_config("<arch-id>")`` for every assigned arch.

IDs use the assigned dashed names; module files use underscores.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig,  # noqa
                                cell_runnable, input_specs)

ARCHS: List[str] = [
    "dbrx-132b",
    "llama4-maverick-400b-a17b",
    "seamless-m4t-large-v2",
    "yi-6b",
    "qwen1.5-4b",
    "qwen2.5-3b",
    "granite-3-2b",
    "internvl2-2b",
    "mamba2-2.7b",
    "recurrentgemma-9b",
]


def _module_name(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(_module_name(arch)).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return importlib.import_module(_module_name(arch)).SMOKE
