"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206, encoder-decoder, multimodal. The speech frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, S, d_model) to the
encoder. vocab padded 256206 -> 256256 for 16-way TP (Megatron-style).
[arXiv:2308.11596]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64,
    norm="layernorm", act="gelu", gated_ffn=False, rope_theta=10_000.0,
    enc_layers=24, frontend="frames",
)

SMOKE = dataclasses.replace(
    CONFIG, name="seamless-smoke", num_layers=2, enc_layers=2, d_model=64,
    num_heads=4, kv_heads=4, head_dim=16, d_ff=128, vocab=256)
