"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, RG-LRU + local attention (window 2048), pattern 1 attn : 2
recurrent -> (rglru, rglru, local_attn) x 12 + (rglru, rglru) tail.
[arXiv:2402.19427]"""
import dataclasses

from repro.configs.base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    norm="rmsnorm", act="gelu", gated_ffn=True, rope_theta=10_000.0,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "local_attn"),
                        window=2048, lru_dim=4096),
)

SMOKE = dataclasses.replace(
    CONFIG, name="rgemma-smoke", num_layers=5, d_model=64, num_heads=4,
    kv_heads=1, head_dim=16, d_ff=128, vocab=256,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "local_attn"),
                        window=16, lru_dim=64))
