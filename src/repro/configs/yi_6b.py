"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA. [arXiv:2403.04652]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, kv_heads=4,
    d_ff=11008, vocab=64000, head_dim=128,
    norm="rmsnorm", act="silu", gated_ffn=True, rope_theta=5_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="yi-smoke", num_layers=2, d_model=64, num_heads=4,
    kv_heads=2, head_dim=16, d_ff=128, vocab=256)
