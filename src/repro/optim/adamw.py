"""AdamW with configurable moment dtype, global-norm clipping and schedules.

Built from scratch (no optax in this environment). Moments can be held in
bf16 for very large models (llama4-maverick) — see DESIGN.md memory notes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init(cfg: AdamWConfig, params) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)

    def z(p):
        return jnp.zeros(p.shape, mdt)

    return {"m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, grads, opt_state, params
          ) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    """One AdamW update. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = schedule(cfg, step)
    mdt = jnp.dtype(cfg.moment_dtype)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m2 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v2 = cfg.b2 * v32 + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m2 / b1c, v2 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(mdt), v2.astype(mdt))

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    treedef = jax.tree.structure(params)
    leaves = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [t[0] for t in leaves])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in leaves])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in leaves])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
