"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
composes with "data" for batch/FSDP by default (see parallel/sharding.py).

Mesh construction goes through ``repro.compat.make_mesh`` so the
``axis_types=Auto`` annotation is applied on JAX >= 0.5 and dropped on
0.4.x (where every axis is implicitly Auto and the kwarg doesn't exist).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh((n // model, model), ("data", "model"))
