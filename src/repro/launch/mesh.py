"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis
composes with "data" for batch/FSDP by default (see parallel/sharding.py).

Mesh construction goes through ``repro.compat.make_mesh`` so the
``axis_types=Auto`` annotation is applied on JAX >= 0.5 and dropped on
0.4.x (where every axis is implicitly Auto and the kwarg doesn't exist).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1, *,
                   devices=None) -> jax.sharding.Mesh:
    """Tiny ("data", "model") mesh over the actually-available devices
    (tests/examples), or over an explicit ``devices`` subset — which is
    how the replica router gives each engine replica its own disjoint
    slice of the host's devices, and how forced-host-device tests
    (XLA_FLAGS=--xla_force_host_platform_device_count=N) pin a mesh to
    fewer devices than the backend exposes."""
    devs = list(devices) if devices is not None else list(jax.devices())
    n = len(devs)
    if model < 1 or n % model != 0:
        raise ValueError(
            f"make_host_mesh: cannot fold {n} device(s) into a "
            f"(data, model) mesh with model={model} — n must be a "
            f"positive multiple of model")
    return make_mesh((n // model, model), ("data", "model"), devices=devs)
