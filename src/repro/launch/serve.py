"""Serving launcher: batched request serving for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      [--slots 4] [--requests 8] [--max-new 12]
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import api
from repro.runtime.serving import Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[launch.serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.slots} slots")
    params = api.init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                        temperature=args.temperature)
    reqs = [Request(rid=i,
                    prompt=[(11 * i + j) % cfg.vocab for j in range(4 + i % 5)],
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.run_to_completion(reqs, max_steps=5000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"[launch.serve] {len(done)}/{len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
