"""Serving launcher: batched request serving for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      [--slots 4] [--requests 8] [--max-new 12] [--engine paged|dense] \
      [--page-size 16] [--num-pages N] [--paged-attn kernel|gather] \
      [--prefix-cache] [--spec-k K]

Every decoder-only stack defaults to the paged KV-cache engine (continuous
batching over a shared page pool, bucketed prefill) — hybrid stacks
included: sliding-window layers get paged ring buffers whose pages are
recycled as they slide out of the window (O(window) live pages per
request), recurrent layers get fixed-size state slots. Only
encoder-decoder stacks fall back to the dense-slot engine (with a warning
naming any paged-engine kwargs that fallback drops).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import api
from repro.runtime.serving import (DenseServingEngine, PagedServingEngine,
                                   Request, ServingEngine)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--engine", choices=["auto", "paged", "dense"],
                    default="auto")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="usable KV pages (default: slots*max_len/page)")
    ap.add_argument("--paged-attn", choices=["kernel", "gather"],
                    default="kernel",
                    help="paged decode attention: in-kernel block-table "
                         "gather (Pallas flash-decode) or the PR-1 dense "
                         "pool gather baseline")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share KV pages across requests with a common "
                         "prompt prefix (radix tree + refcounted "
                         "copy-on-write pages; paged engine only)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode: verify up to K prompt-lookup "
                         "drafted tokens per multi-token step (exact "
                         "greedy; paged engine only, temperature 0)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[launch.serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.slots} slots")
    params = api.init_params(cfg, jax.random.key(0))
    common = dict(slots=args.slots, max_len=args.max_len,
                  temperature=args.temperature)
    if args.engine == "dense":
        eng = DenseServingEngine(cfg, params, **common)
    elif args.engine == "paged":
        eng = PagedServingEngine(cfg, params, page_size=args.page_size,
                                 num_pages=args.num_pages,
                                 attn_impl=args.paged_attn,
                                 prefix_cache=args.prefix_cache,
                                 spec_k=args.spec_k, **common)
    else:
        eng = ServingEngine(cfg, params, page_size=args.page_size,
                            num_pages=args.num_pages,
                            attn_impl=args.paged_attn,
                            prefix_cache=args.prefix_cache,
                            spec_k=args.spec_k, **common)
    print(f"[launch.serve] engine: {type(eng).__name__}")
    # production-shaped traffic: every request opens with the same system
    # prompt (what --prefix-cache shares), tails vary in length (what the
    # paged engine's buckets absorb)
    sys_prompt = [(5 * j + 2) % cfg.vocab for j in range(2 * args.page_size)]
    reqs = [Request(rid=i,
                    prompt=sys_prompt
                    + [(11 * i + j) % cfg.vocab for j in range(4 + i % 5)],
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.run_to_completion(reqs, max_steps=5000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    print(f"[launch.serve] {len(done)}/{len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, {eng.prefill_traces} prefill traces")
    if isinstance(eng, PagedServingEngine):
        st = eng.pool_stats()
        print(f"[launch.serve] kv pages: peak {st.peak_pages}/{st.num_pages} "
              f"({st.peak_pages * st.page_size} tokens reserved at peak vs "
              f"{st.dense_equiv_tokens} dense)")
        if eng.has_win:
            print(f"[launch.serve] sliding window ({eng.window} tokens): "
                  f"{eng.win_recycled_pages} pages recycled as they slid "
                  f"out (live window pages per request capped at "
                  f"{eng.win_pages_bound(args.max_len)})")
        if eng.prefix is not None:
            ps = eng.prefix_stats()
            print(f"[launch.serve] prefix cache: hit rate "
                  f"{ps['hit_rate']:.2f}, {ps['shared_token_frac']:.0%} of "
                  f"prompt tokens served from cache, "
                  f"{ps['prefill_tokens_saved']:.0f} prefill tokens saved, "
                  f"{ps['cow_copies']:.0f} CoW copies")
        if eng.spec_k:
            ss = eng.spec_stats()
            print(f"[launch.serve] speculative (K={eng.spec_k}): "
                  f"{ss['accepted_per_step']:.2f} tokens/request/step, "
                  f"accept rate {ss['accept_rate']:.2f} "
                  f"({ss['spec_accepted']:.0f}/{ss['spec_drafted']:.0f} "
                  f"drafts)")


if __name__ == "__main__":
    main()
