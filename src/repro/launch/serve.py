"""Serving launcher: batched request serving for any assigned arch.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      [--slots 4] [--requests 8] [--max-new 12] [--engine paged|dense] \
      [--page-size 16] [--num-pages N] [--paged-attn kernel|gather] \
      [--prefix-cache] [--spec-k K] [--draft-model ARCH] [--shards M] \
      [--replicas R] [--host-tier] [--temperature T] [--top-k K] \
      [--top-p P] [--trace [trace.json]]

Every decoder-only stack defaults to the paged KV-cache engine (continuous
batching over a shared page pool, bucketed prefill) — hybrid stacks
included: sliding-window layers get paged ring buffers whose pages are
recycled as they slide out of the window (O(window) live pages per
request), recurrent layers get fixed-size state slots. Only
encoder-decoder stacks fall back to the dense-slot engine (with a warning
naming any paged-engine kwargs that fallback drops).

``--shards M`` serves tensor-parallel over M devices (KV pools + attn/mlp
weights sharded on a ("data","model") mesh; same greedy tokens as M=1);
``--replicas R`` runs R data-parallel engine replicas behind a router
(R x M devices total — on CPU, force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import api
from repro.runtime.drafter import DraftModelDrafter
from repro.runtime.router import make_replicas
from repro.runtime.sampling import SamplingParams
from repro.runtime.serving import (DenseServingEngine, PagedServingEngine,
                                   Request, ServingEngine)
from repro.runtime.trace import Tracer, set_default_tracer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="decode policy: 0 = greedy (default), > 0 samples "
                         "(runtime/sampling.py — works with --spec-k via "
                         "rejection-sampled verification)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the K highest logits before sampling "
                         "(0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest logit prefix "
                         "with cumulative mass >= P (1.0 = off)")
    ap.add_argument("--engine", choices=["auto", "paged", "dense"],
                    default="auto")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="usable KV pages (default: slots*max_len/page)")
    ap.add_argument("--paged-attn", choices=["kernel", "gather"],
                    default="kernel",
                    help="paged decode attention: in-kernel block-table "
                         "gather (Pallas flash-decode) or the PR-1 dense "
                         "pool gather baseline")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share KV pages across requests with a common "
                         "prompt prefix (radix tree + refcounted "
                         "copy-on-write pages; paged engine only)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode: verify up to K drafted tokens "
                         "per multi-token step by rejection sampling "
                         "(distribution-preserving at any temperature; "
                         "exact greedy at temperature 0; paged engine only)")
    ap.add_argument("--draft-model", default=None, metavar="ARCH",
                    help="draft with a small second model (any attention-"
                         "only arch; smoke-sized) instead of the built-in "
                         "n-gram prompt lookup; needs --spec-k > 0")
    ap.add_argument("--host-tier", action="store_true",
                    help="two-tier KV: demote idle/preempted pages (and "
                         "recurrent state) to host RAM and promote them "
                         "back through a prefetch stream instead of "
                         "evict + re-prefill (paged engine, single shard)")
    ap.add_argument("--shards", type=int, default=1,
                    help="tensor-parallel shards per engine: KV pools and "
                         "attn/mlp weights shard over a ('data','model') "
                         "mesh of this many devices (paged engine only)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind a router "
                         "(each replica gets --shards devices; paged "
                         "engine only)")
    ap.add_argument("--route", choices=["hash", "least_loaded"],
                    default="hash", help="replica routing policy")
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="TRACE.JSON",
                    help="record per-tick spans and print the per-phase "
                         "wall breakdown; with a filename, also export "
                         "Chrome Trace Event JSON (open in Perfetto)")
    args = ap.parse_args()

    # install the tracer BEFORE engine construction: engines capture the
    # process default at init
    tracer = Tracer(enabled=True) if args.trace is not None else None
    if tracer is not None:
        set_default_tracer(tracer)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[launch.serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.slots} slots")
    params = api.init_params(cfg, jax.random.key(0))
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p).validate()
    drafter = None
    if args.draft_model is not None:
        if args.spec_k <= 0:
            raise SystemExit("--draft-model drafts feed the speculative "
                             "verify step — pass --spec-k > 0 with it")
        if args.draft_model not in ARCHS:
            raise SystemExit(f"--draft-model must be one of {ARCHS}")
        dcfg = get_smoke_config(args.draft_model)
        dparams = api.init_params(dcfg, jax.random.key(1))
        drafter = DraftModelDrafter(dcfg, dparams, max_len=args.max_len,
                                    attn_impl=args.paged_attn)
        print(f"[launch.serve] draft model: {dcfg.name} "
              f"({dcfg.param_count()/1e6:.1f}M params)")
    common = dict(slots=args.slots, max_len=args.max_len, sampling=sampling)
    paged_kw = dict(page_size=args.page_size, num_pages=args.num_pages,
                    attn_impl=args.paged_attn,
                    prefix_cache=args.prefix_cache, spec_k=args.spec_k,
                    drafter=drafter, host_tier=args.host_tier)
    router = None
    if args.replicas > 1:
        if args.engine == "dense":
            raise SystemExit("--replicas needs the paged engine")
        router = make_replicas(cfg, params, replicas=args.replicas,
                               model=args.shards, policy=args.route,
                               **paged_kw, **common)
        eng = router.engines[0]          # telemetry shape reference
        print(f"[launch.serve] router: {args.replicas} replica(s) x "
              f"{args.shards} shard(s) over {len(jax.devices())} "
              f"device(s), policy {args.route}")
    elif args.engine == "dense":
        eng = DenseServingEngine(cfg, params, **common)
    else:
        mesh = None
        if args.shards > 1:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(model=args.shards,
                                  devices=jax.devices()[:args.shards])
        builder = PagedServingEngine if args.engine == "paged" \
            else ServingEngine
        eng = builder(cfg, params, mesh=mesh, **paged_kw, **common)
    print(f"[launch.serve] engine: {type(eng).__name__}")
    # production-shaped traffic: every request opens with the same system
    # prompt (what --prefix-cache shares), tails vary in length (what the
    # paged engine's buckets absorb)
    sys_prompt = [(5 * j + 2) % cfg.vocab for j in range(2 * args.page_size)]
    reqs = [Request(rid=i,
                    prompt=sys_prompt
                    + [(11 * i + j) % cfg.vocab for j in range(4 + i % 5)],
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    driver = router if router is not None else eng
    done = driver.run_to_completion(reqs, max_steps=5000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    traces = sum(e.prefill_traces for e in router.engines) \
        if router is not None else eng.prefill_traces
    print(f"[launch.serve] {len(done)}/{len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, {traces} prefill traces")
    if router is not None:
        rs = router.stats()
        print(f"[launch.serve] routed per replica: {rs['routed']}, peak "
              f"pages per replica: "
              f"{[int(p) for p in rs['peak_pages_per_replica']]}, "
              f"preemptions: {rs['preempted']}")
    if isinstance(eng, PagedServingEngine):
        for e_i, e in enumerate(router.engines if router is not None
                                else [eng]):
            ss = e.shard_stats()
            if ss["model_shards"] > 1:
                print(f"[launch.serve] replica {e_i}: "
                      f"{int(ss['model_shards'])} shards "
                      f"({ss['sharded_axes']}), peak "
                      f"{int(ss['peak_pages_per_shard'])} pages/shard, "
                      f"{int(ss['pool_bytes_per_shard'])} pool bytes/shard")
        st = eng.pool_stats()
        print(f"[launch.serve] kv pages: peak {st.peak_pages}/{st.num_pages} "
              f"({st.peak_pages * st.page_size} tokens reserved at peak vs "
              f"{st.dense_equiv_tokens} dense)")
        if eng.has_win:
            print(f"[launch.serve] sliding window ({eng.window} tokens): "
                  f"{eng.win_recycled_pages} pages recycled as they slid "
                  f"out (live window pages per request capped at "
                  f"{eng.win_pages_bound(args.max_len)})")
        if eng.prefix is not None:
            ps = eng.prefix_stats()
            print(f"[launch.serve] prefix cache: hit rate "
                  f"{ps['hit_rate']:.2f}, {ps['shared_token_frac']:.0%} of "
                  f"prompt tokens served from cache, "
                  f"{ps['prefill_tokens_saved']:.0f} prefill tokens saved, "
                  f"{ps['cow_copies']:.0f} CoW copies")
        if eng.tier is not None:
            ts = eng.tier_stats()
            print(f"[launch.serve] host tier: {ts['swap_outs']:.0f} swap-"
                  f"outs / {ts['swap_ins']:.0f} swap-ins, "
                  f"{ts['demoted_pages']:.0f} pages demoted / "
                  f"{ts['promoted_pages']:.0f} promoted, "
                  f"{ts['reprefill_tokens_saved']:.0f} re-prefill tokens "
                  f"saved, prefetch hit rate {ts['prefetch_hit_rate']:.2f}, "
                  f"{ts['host_bytes_peak']:.0f} host bytes at peak")
        if eng.spec_k:
            ss = eng.spec_stats()
            print(f"[launch.serve] speculative (K={eng.spec_k}, drafter "
                  f"{ss['drafter']}): "
                  f"{ss['accepted_per_step']:.2f} tokens/request/step, "
                  f"accept rate {ss['accept_rate']:.2f} "
                  f"({ss['spec_accepted']:.0f}/{ss['spec_drafted']:.0f} "
                  f"drafts)")
            if eng.drafter is not None and eng.drafter.kind == "model":
                ds = eng.drafter.stats()
                print(f"[launch.serve] draft model: "
                      f"{ds['draft_proposed']:.0f} tokens proposed over "
                      f"{ds['draft_decode_calls']:.0f} decode calls, "
                      f"{ds['draft_ingested_tokens']:.0f} tokens ingested, "
                      f"{ds['draft_pool_rejects']:.0f} pool rejects")
    m = eng.metrics()
    if not sampling.is_greedy:
        print(f"[launch.serve] decode policy: temperature "
              f"{sampling.temperature}, top_k {sampling.top_k}, top_p "
              f"{sampling.top_p} — {m['sampling.sampled_tokens']:.0f} "
              f"sampled tokens, "
              f"{m['sampling.step_traces'] + m['sampling.spec_traces']:.0f} "
              f"decode traces (policy-mix invariant)")
    print(f"[launch.serve] latency: ttft p50 {m['latency.ttft_p50_s']:.4f}s "
          f"/ p95 {m['latency.ttft_p95_s']:.4f}s, tpot p50 "
          f"{m['latency.tpot_p50_s']:.4f}s / p95 "
          f"{m['latency.tpot_p95_s']:.4f}s, temporal util "
          f"{m['util.temporal']:.2f}")
    if tracer is not None:
        set_default_tracer(None)
        print("[launch.serve] per-phase wall breakdown (nested spans "
              "overlap their parents):")
        print(tracer.format_phase_walls())
        if args.trace:
            tracer.export(args.trace)
            print(f"[launch.serve] wrote {args.trace}: "
                  f"{len(tracer.events())} events "
                  f"({tracer.dropped_events} dropped) — open in Perfetto "
                  f"(https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
