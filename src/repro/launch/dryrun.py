"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/run before any other jax usage: the first two lines pin
512 placeholder host devices so the production meshes can be built.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-too]
Outputs one JSON per cell under experiments/dryrun/.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402

from repro import compat                               # noqa: E402
from repro.analysis import roofline as rl              # noqa: E402
from repro.configs import (ARCHS, SHAPES, cell_runnable,  # noqa: E402
                           get_config)
from repro.launch.mesh import make_production_mesh     # noqa: E402
from repro.launch.steps import make_step               # noqa: E402
from repro.parallel.sharding import Rules              # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules_table=None, out_dir: str = OUT_DIR, tag: str = "",
             donate_cache: bool = False, cfg_patch=None,
             verbose: bool = True):
    cfg = get_config(arch)
    if cfg_patch:
        import dataclasses
        cfg = dataclasses.replace(cfg, **cfg_patch)
    shape = SHAPES[shape_name]
    ok, why = cell_runnable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    if not ok:
        return {"cell": cell, "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = Rules(mesh, rules_table)
    t0 = time.time()
    rec = {"cell": cell, "arch": arch, "shape": shape_name,
           "mesh": mesh_name, "chips": chips}
    try:
        fn, args, in_sh, out_sh = make_step(cfg, shape, rules)
        donate = ()
        if donate_cache and shape.kind == "decode":
            donate = (1,)            # alias the KV/state cache in->out
        with compat.set_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*jax.tree.map(lambda x: x, args))
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            r = rl.analyze(compiled, chips=chips,
                           model_flops=rl.model_flops_for(cfg, shape),
                           hlo_text=hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": None if mem is None else {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "total_bytes_per_device": (
                    mem.argument_size_in_bytes + mem.temp_size_in_bytes
                    + mem.output_size_in_bytes - mem.alias_size_in_bytes),
            },
            "roofline": r.to_dict(),
            "hlo_bytes": len(hlo),
        })
        if verbose:
            mm = rec["memory"]["total_bytes_per_device"] / 2**30
            print(f"[dryrun] {cell}: OK compile={t_compile:.1f}s "
                  f"mem/dev={mm:.2f}GiB bottleneck={r.bottleneck} "
                  f"t=({r.t_compute:.4f},{r.t_memory:.4f},"
                  f"{r.t_collective:.4f})s", flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()})
        if verbose:
            print(f"[dryrun] {cell}: FAIL {type(e).__name__}: {e}",
                  flush=True)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def optimized_kwargs(shape_name: str) -> dict:
    """The tuned configuration from EXPERIMENTS.md §Perf: context/sequence
    parallelism + one-shot attention for batch steps; context-parallel
    donated caches + single-pass decode attention for decode steps."""
    if SHAPES[shape_name].kind == "decode":
        return {"rules_table": {"seq": "model"}, "donate_cache": True,
                "cfg_patch": {"decode_kv_chunk": 0}}
    return {"rules_table": {"seq": "model"},
            "cfg_patch": {"flash_chunking": False}}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--multi-pod-too", action="store_true",
                    help="run each cell on both meshes")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the EXPERIMENTS.md §Perf tuned options")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.multi_pod_too else [args.multi_pod]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                kw = optimized_kwargs(shape) if args.optimized else {}
                rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                               tag=args.tag, **kw)
                cells.append(rec)
                n_fail += rec["status"] == "error"
    n_ok = sum(r["status"] == "ok" for r in cells)
    n_skip = sum(r["status"] == "skipped" for r in cells)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"of {len(cells)} cells")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
