"""Jittable step functions + their sharding specs for launcher/dry-run use.

The dry-run lowers exactly these steps — the same code the trainer/server
runs, so a passing dry-run certifies the production path.
"""
from __future__ import annotations

import functools

import jax

from repro.configs.base import ModelConfig, ShapeConfig, input_specs
from repro.models import api
from repro.optim import adamw
from repro.parallel.sharding import Rules
from repro.runtime import trainer as trainer_mod

BATCH_AXES = {
    "tokens": "batch,seq",
    "labels": "batch,seq",
    "pos": "batch",
    "enc_embeds": "batch,seq,embed",
    "frontend_embeds": "batch,seq,embed",
}


def opt_config_for(cfg: ModelConfig) -> adamw.AdamWConfig:
    return adamw.AdamWConfig(moment_dtype=cfg.moment_dtype)


def state_shapes(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    p = api.param_shapes(cfg)
    return {"params": p,
            "opt": jax.eval_shape(functools.partial(adamw.init, opt_cfg), p)}


def state_axes(cfg: ModelConfig):
    pa = api.param_axes(cfg)
    return {"params": pa,
            "opt": {"m": pa, "v": pa, "step": ""}}


def make_step(cfg: ModelConfig, shape: ShapeConfig, rules: Rules):
    """Returns (fn, in_specs_tree(ShapeDtypeStruct), in_shardings,
    out_shardings_or_None) for the cell's step kind."""
    opt_cfg = opt_config_for(cfg)
    ins = input_specs(cfg, shape)

    if shape.kind == "train":
        step = trainer_mod.make_train_step(cfg, opt_cfg, rules=rules)
        sshapes = state_shapes(cfg, opt_cfg)
        saxes = state_axes(cfg)
        state_sh = rules.tree_shardings(sshapes, saxes)
        batch_sh = {k: rules.sharding(v.shape, BATCH_AXES[k])
                    for k, v in ins.items()}
        args = (sshapes, ins)
        in_sh = (state_sh, batch_sh)
        out_sh = (state_sh, None)
        return step, args, in_sh, out_sh

    if shape.kind == "prefill":
        def fn(params, batch):
            logits, cache, pos = api.prefill(cfg, params, batch, rules=rules)
            return logits, cache, pos

        pshapes = api.param_shapes(cfg)
        psh = rules.tree_shardings(pshapes, api.param_axes(cfg))
        batch_sh = {k: rules.sharding(v.shape, BATCH_AXES[k])
                    for k, v in ins.items()}
        return fn, (pshapes, ins), (psh, batch_sh), None

    # decode
    def fn(params, cache, tokens, pos):
        return api.decode_step(cfg, params, cache, tokens, pos, rules=rules)

    pshapes = api.param_shapes(cfg)
    psh = rules.tree_shardings(pshapes, api.param_axes(cfg))
    cache_sh = rules.tree_shardings(ins["cache"], api.cache_axes(cfg))
    tok_sh = rules.sharding(ins["tokens"].shape, "batch,seq")
    pos_sh = rules.sharding(ins["pos"].shape, "batch")
    args = (pshapes, ins["cache"], ins["tokens"], ins["pos"])
    in_sh = (psh, cache_sh, tok_sh, pos_sh)
    out_sh = (None, cache_sh)
    return fn, args, in_sh, out_sh
