"""Training launcher: ``--arch`` selects any assigned architecture.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 30 [--ckpt-dir /tmp/ckpt] [--grad-accum 2] [--compress-grads]

``--smoke`` runs the reduced same-family config on local devices; without
it, the full config is used (real-hardware path; on CPU it will OOM —
that is what the dry-run is for).
"""
from __future__ import annotations

import argparse


from repro import compat

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data.synthetic import DataConfig, SyntheticDataset
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.parallel.sharding import NO_RULES, Rules
from repro.runtime.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=0)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[launch.train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    if args.model_parallel > 1:
        mesh = make_host_mesh(model=args.model_parallel)
        rules = Rules(mesh)
        ctx = compat.set_mesh(mesh)
    else:
        rules, ctx = NO_RULES, None

    ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                     global_batch=args.batch))
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=max(5, args.steps // 10),
                            total_steps=args.steps,
                            moment_dtype=cfg.moment_dtype)

    def run():
        tr = Trainer(cfg, opt, ds, rules=rules, ckpt_dir=args.ckpt_dir,
                     save_every=args.save_every, grad_accum=args.grad_accum,
                     compress_grads=args.compress_grads, log_every=10)
        tr.run(args.steps)
        return tr

    if ctx is not None:
        with ctx:
            tr = run()
    else:
        tr = run()
    print(f"[launch.train] done at step {tr.step}; "
          f"{tr.monitor.slow_steps} straggler-flagged steps")


if __name__ == "__main__":
    main()
