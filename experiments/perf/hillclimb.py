"""Perf hillclimb driver: run named variants of the three chosen cells
and print the roofline deltas. Each variant is one hypothesis from
EXPERIMENTS.md §Perf.

  PYTHONPATH=src python experiments/perf/hillclimb.py <variant> [...]
  PYTHONPATH=src python experiments/perf/hillclimb.py --list
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
import sys                                           # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

from repro.launch.dryrun import run_cell             # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "runs")

# variant name -> (arch, shape, run_cell kwargs)
VARIANTS = {
    # --- Cell A: qwen1.5-4b x train_4k (worst roofline fraction) --------
    "A0_base": ("qwen1.5-4b", "train_4k", {}),
    "A1_seqsp": ("qwen1.5-4b", "train_4k",
                 {"rules_table": {"seq": "model"}}),
    "A2_seqsp_dots": ("qwen1.5-4b", "train_4k",
                      {"rules_table": {"seq": "model"},
                       "cfg_patch": {"remat": "dots"}}),
    "A3_dots": ("qwen1.5-4b", "train_4k", {"cfg_patch": {"remat": "dots"}}),
    "A4_seqsp_oneshot": ("qwen1.5-4b", "train_4k",
                         {"rules_table": {"seq": "model"},
                          "cfg_patch": {"flash_chunking": False}}),
    # --- Cell B: dbrx-132b x train_4k (most collective-bound) -----------
    "B0_base": ("dbrx-132b", "train_4k", {}),
    "B1_seqsp": ("dbrx-132b", "train_4k",
                 {"rules_table": {"seq": "model"}}),
    "B2_moment_bf16": ("dbrx-132b", "train_4k",
                       {"cfg_patch": {"moment_dtype": "bfloat16"}}),
    "B3_moe_cons": ("dbrx-132b", "train_4k", {}),   # after moe_apply cons fix
    "B4_moe_cons_oneshot": ("dbrx-132b", "train_4k",
                            {"rules_table": {"seq": "model"},
                             "cfg_patch": {"flash_chunking": False}}),
    "B5_capacity_shard": ("dbrx-132b", "train_4k",
                          {"rules_table": {"seq": "model"},
                           "cfg_patch": {"flash_chunking": False}}),
    "B6_grouped_dispatch": ("dbrx-132b", "train_4k",
                            {"rules_table": {"seq": "model"},
                             "cfg_patch": {"flash_chunking": False}}),
    # --- Cell C: qwen2.5-3b x decode_32k (paper-representative) ---------
    "C0_base": ("qwen2.5-3b", "decode_32k", {}),
    "C1_donate": ("qwen2.5-3b", "decode_32k", {"donate_cache": True}),
    "C2_ctxpar": ("qwen2.5-3b", "decode_32k",
                  {"donate_cache": True, "rules_table": {"seq": "model"}}),
    "C3_onehot": ("qwen2.5-3b", "decode_32k",
                  {"donate_cache": True, "rules_table": {"seq": "model"},
                   "cfg_patch": {"decode_kv_chunk": 0}}),
    "C4_int8_cache": ("qwen2.5-3b", "decode_32k",
                      {"donate_cache": True,
                       "rules_table": {"seq": "model"},
                       "cfg_patch": {"decode_kv_chunk": 0,
                                     "kv_cache_dtype": "int8"}}),
}


def main() -> None:
    names = sys.argv[1:]
    if not names or names[0] == "--list":
        print("\n".join(VARIANTS))
        return
    for name in names:
        arch, shape, kw = VARIANTS[name]
        rec = run_cell(arch, shape, out_dir=OUT, tag=name, **kw)
        if rec.get("status") == "ok":
            rl = rec["roofline"]
            print(f"[{name}] mem/dev="
                  f"{rec['memory']['total_bytes_per_device']/2**30:.2f}GiB "
                  f"t=({rl['t_compute_s']:.4f},{rl['t_memory_s']:.4f},"
                  f"{rl['t_collective_s']:.4f})s "
                  f"useful={rl['useful_flops_frac']:.3f} "
                  f"frac={rl['roofline_frac']:.4f} "
                  f"coll={ {k: round(v/2**30,1) for k,v in rl['coll_breakdown'].items()} }")
        else:
            print(f"[{name}] {rec.get('status')}: "
                  f"{rec.get('error', rec.get('reason'))}")


if __name__ == "__main__":
    main()
