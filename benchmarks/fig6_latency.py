"""Fig. 6(c): total latency, shared+PDMA vs separated buffers.

Paper claims: 1.15-2.36x total-latency reduction; the separated config
shows slightly better GEMM-core cycles (no bank contention) but much
larger DMA cycles — both effects are reported per workload.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import geomean
from repro.core import simulator, workloads


def run() -> List[Dict]:
    rows = []
    gains = []
    for name, wl in workloads.all_workloads().items():
        r = simulator.latency_report(wl)
        gains.append(r["gain_serial"])
        rows.append({
            "bench": "fig6c_latency", "workload": name,
            "voltra_compute_Mcyc": r["voltra_compute_cycles"] / 1e6,
            "voltra_dma_Mcyc": r["voltra_dma_cycles"] / 1e6,
            "sep_compute_Mcyc": r["separated_compute_cycles"] / 1e6,
            "sep_dma_Mcyc": r["separated_dma_cycles"] / 1e6,
            "gain_serial": r["gain_serial"],
            "gain_overlap": r["gain_overlap"],
        })
    rows.append({"bench": "fig6c_latency", "workload": "GEOMEAN",
                 "voltra_compute_Mcyc": "", "voltra_dma_Mcyc": "",
                 "sep_compute_Mcyc": "", "sep_dma_Mcyc": "",
                 "gain_serial": geomean(gains), "gain_overlap": ""})
    rows.append({"bench": "fig6c_latency", "workload": "PAPER_ANCHOR",
                 "voltra_compute_Mcyc": "", "voltra_dma_Mcyc": "",
                 "sep_compute_Mcyc": "", "sep_dma_Mcyc": "",
                 "gain_serial": "1.15-2.36", "gain_overlap": ""})
    return rows
