"""CI gate over the bench-smoke CSV: equivalence columns must hold.

The serving benchmarks carry correctness contracts inside the perf CSV —
``tokens_match_tp1`` (every tensor-parallel shard count emits the
single-shard engine's exact greedy tokens), ``tokens_match_unconstrained``
(a pool capped far below the working set, evict-only or host-tiered,
emits the unconstrained engine's exact greedy tokens) and
``tokens_match_greedy`` (the sampling scenario's greedy speculative rows
— n-gram and draft-model drafted alike — emit the plain greedy engine's
exact tokens; rejection-sampled verification at temperature 0 IS exact
greedy). A perf artifact whose equivalence column is 0 is not a slow data
point, it's a wrong one — so CI fails the build instead of uploading it.

Rules, applied to every ``tokens_match_*`` column in every section:

* every non-empty cell must be exactly ``1`` (``0`` = mismatch = FAIL;
  empty = the row predates the column / is a ratio row, allowed);
* each REQUIRED column (``tokens_match_tp1``,
  ``tokens_match_unconstrained``, ``tokens_match_greedy``) must appear
  with at least one ``1``
  somewhere in the file — a silently-dropped scenario must not pass the
  gate by absence (skip-note rows don't count: a run where every sharded
  leg was skipped still fails, loudly, so the CI leg without forced host
  devices is visibly not covering the contract);
* each unified metrics column (``ttft_p50`` / ``ttft_p95`` / ``tpot_p50``
  / ``tpot_p95`` / ``temporal_util``) must appear with at least one
  non-empty numeric cell, and every ``temporal_util`` value must lie in
  [0, 1] — the serve rows carry the ``engine.metrics()`` latency/
  utilization surface and a build that dropped it must not ship a CSV
  that merely looks complete.

Input format: ``benchmarks/run.py --out`` artifacts — one CSV block per
suite behind a ``# === name ===`` header — or a bare single-suite CSV
from ``python -m benchmarks.serve_bench``.

  python -m benchmarks.check_csv bench-smoke.csv
"""
from __future__ import annotations

import argparse
import csv
import io
import sys
from typing import Dict, List, Tuple

REQUIRED = ("tokens_match_tp1", "tokens_match_unconstrained",
            "tokens_match_greedy")

# unified latency/utilization columns (ISSUE 8): every serve scenario row
# must carry them, so the artifact must contain each with at least one
# non-empty (float-parsable) cell — a metrics() surface that silently
# stopped flowing into the CSV must fail the gate, not upload zeros-by-
# absence. temporal_util is a ratio by construction: any parsed value
# outside [0, 1] is a broken timer, not a data point.
REQUIRED_METRICS = ("ttft_p50", "ttft_p95", "tpot_p50", "tpot_p95",
                    "temporal_util")


def parse_sections(text: str) -> List[Tuple[str, List[Dict[str, str]]]]:
    """Split a run.py artifact into (section_name, rows) pairs. Lines
    starting with ``#`` delimit sections; the first non-comment line of
    each section is its header. Cells are RFC-4180 CSV (``emit()`` quotes
    fields with embedded commas — engine names like ``paged[kernel,tp2]``,
    skip notes)."""
    sections: List[Tuple[str, List[Dict[str, str]]]] = []
    name, header, rows = "", None, []

    def flush():
        if header is not None:
            sections.append((name, rows))

    for line in text.splitlines():
        if not line.strip():
            continue
        if line.lstrip().startswith("#"):
            flush()
            name = line.strip().strip("#= ").strip() or name
            header, rows = None, []
            continue
        cells = next(csv.reader(io.StringIO(line)))
        if header is None:
            header = cells
        else:
            # short rows pad with "" (emit() never writes them, but be
            # liberal in what we accept from hand-concatenated artifacts)
            cells += [""] * (len(header) - len(cells))
            rows.append(dict(zip(header, cells)))
    flush()
    return sections


def check(text: str) -> List[str]:
    """Return the list of violations (empty = gate passes)."""
    errors: List[str] = []
    seen_ok: Dict[str, int] = {k: 0 for k in REQUIRED}
    seen_metric: Dict[str, int] = {k: 0 for k in REQUIRED_METRICS}
    sections = parse_sections(text)
    if not any(rows for _, rows in sections):
        return ["no CSV rows found — empty or truncated artifact"]
    for name, rows in sections:
        for i, row in enumerate(rows):
            for col, val in row.items():
                if col in seen_metric and val != "":
                    eng = row.get("engine", f"row {i}")
                    try:
                        x = float(val)
                    except ValueError:
                        errors.append(
                            f"[{name or 'csv'}] {eng}: {col}={val!r} is "
                            f"not a number")
                        continue
                    if col == "temporal_util" and not 0.0 <= x <= 1.0:
                        errors.append(
                            f"[{name or 'csv'}] {eng}: temporal_util={x} "
                            f"outside [0, 1] — step wall exceeded tick "
                            f"wall, the timers are broken")
                        continue
                    seen_metric[col] += 1
                if not col.startswith("tokens_match_"):
                    continue
                if val == "":
                    continue
                if val == "1":
                    if col in seen_ok:
                        seen_ok[col] += 1
                    continue
                eng = row.get("engine", f"row {i}")
                errors.append(
                    f"[{name or 'csv'}] {eng}: {col}={val!r} — capped/"
                    f"sharded replay diverged from its baseline tokens")
    for col, n in seen_ok.items():
        if n == 0:
            errors.append(
                f"required equivalence column {col!r} never passed "
                f"(missing column or every leg skipped) — the scenario "
                f"that enforces it did not run")
    for col, n in seen_metric.items():
        if n == 0:
            errors.append(
                f"required metrics column {col!r} missing or empty — the "
                f"unified engine.metrics() surface did not reach the CSV")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("csv", help="bench CSV artifact (run.py --out format)")
    args = ap.parse_args()
    with open(args.csv) as f:
        text = f.read()
    errors = check(text)
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        raise SystemExit(1)
    n = sum(len(rows) for _, rows in parse_sections(text))
    print(f"check_csv: OK — {n} rows, equivalence columns "
          f"{', '.join(REQUIRED)} all green, metrics columns "
          f"{', '.join(REQUIRED_METRICS)} present")


if __name__ == "__main__":
    main()
