"""Benchmark harness: one module per paper table/figure. Prints CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig6a,table1]
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (fig1c_memory, fig4c_mha, fig6_latency, fig6_spatial,
                        fig6_temporal, fig7_efficiency, kernel_bench,
                        serve_bench, table1)
from benchmarks.common import emit

SUITES = {
    "fig6a": fig6_spatial.run,
    "fig6b": fig6_temporal.run,
    "fig6c": fig6_latency.run,
    "fig1c": fig1c_memory.run,
    "fig4c": fig4c_mha.run,
    "fig7": fig7_efficiency.run,
    "table1": table1.run,
    "kernels": kernel_bench.run,
    "serve": serve_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    t0 = time.time()
    for name in names:
        if name not in SUITES:
            raise SystemExit(f"unknown suite {name!r}")
        print(f"# === {name} ===", flush=True)
        rows = SUITES[name]()
        print(emit(rows), flush=True)
        print()
    print(f"# all suites done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
