"""Benchmark harness: one module per paper table/figure. Prints CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig6a,table1] [--smoke]
      [--out results.csv]

``--smoke`` asks each suite that supports it (kernels, serve) for tiny
shapes — seconds instead of minutes — so CI can replay the perf-sensitive
suites per PR and upload the CSV as an artifact (``--out``).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

from benchmarks import (fig1c_memory, fig4c_mha, fig6_latency, fig6_spatial,
                        fig6_temporal, fig7_efficiency, kernel_bench,
                        serve_bench, table1)
from benchmarks.common import emit

SUITES = {
    "fig6a": fig6_spatial.run,
    "fig6b": fig6_temporal.run,
    "fig6c": fig6_latency.run,
    "fig1c": fig1c_memory.run,
    "fig4c": fig4c_mha.run,
    "fig7": fig7_efficiency.run,
    "table1": table1.run,
    "kernels": kernel_bench.run,
    "serve": serve_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for suites that support it (CI)")
    ap.add_argument("--out", default=None,
                    help="also write the CSV output to this file")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace seed for suites that generate random "
                         "traffic (serve): same seed -> same trace, so "
                         "CI CSV artifacts diff cleanly run-to-run")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.JSON",
                    help="suites that support tracing (serve) export a "
                         "Chrome Trace Event JSON of their run here")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    t0 = time.time()
    chunks = []
    for name in names:
        if name not in SUITES:
            raise SystemExit(f"unknown suite {name!r}")
        fn = SUITES[name]
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        if "seed" in inspect.signature(fn).parameters:
            kwargs["seed"] = args.seed
        if args.trace_out and \
                "trace_out" in inspect.signature(fn).parameters:
            kwargs["trace_out"] = args.trace_out
        print(f"# === {name} ===", flush=True)
        csv = emit(fn(**kwargs))
        chunks.append(f"# === {name} ===\n{csv}\n")
        print(csv, flush=True)
        print()
    if args.out:
        with open(args.out, "w") as f:
            f.write("\n".join(chunks))
        print(f"# wrote {args.out}", file=sys.stderr)
    print(f"# all suites done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
