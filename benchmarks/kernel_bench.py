"""Kernel microbenchmarks: interpret-mode wall time (CPU correctness path)
plus the DERIVED TPU roofline terms per kernel invocation — compute bytes/
FLOPs analytically from the block schedule (the dry-run methodology at
kernel granularity). 197 TFLOP/s bf16, 819 GB/s HBM per chip."""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.analysis.roofline import HBM_BW, PEAK_FLOPS
from repro.kernels import ops, ref
from repro.kernels.gemm_os import spatial_utilization


def _gemm_terms(M, K, N, block, dtype_bytes=2):
    bm, bn, bk = block
    nM, nN, nK = -(-M // bm), -(-N // bn), -(-K // bk)
    flops = 2.0 * M * K * N
    # HBM traffic of the grid pipeline: x blocks nN times, w blocks nM
    # times, out once (the output-stationary win: no psum round-trips)
    bytes_hbm = (M * K * nN + K * N * nM) * dtype_bytes + M * N * dtype_bytes
    return flops, bytes_hbm


def _paged_attn_rows(smoke: bool) -> List[Dict]:
    """In-kernel block-table gather vs the dense pool gather, at decode
    shapes. The roofline story: the kernel reads each live page once
    (sum ceil(len/page) page tiles); the gather path reads the whole
    table-width pool slice AND round-trips the materialized (B, S, KV, D)
    buffer through HBM."""
    B, KV, G, D = (2, 2, 4, 64) if smoke else (4, 2, 4, 64)
    page, n_blocks = (16, 4) if smoke else (16, 16)
    H, S = KV * G, page * n_blocks
    P = 1 + B * n_blocks
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, KV, D), jnp.float32)
    bt = jnp.arange(1, P, dtype=jnp.int32).reshape(B, n_blocks)
    lengths = jnp.asarray([S // 4, S // 2, 3 * S // 4, S][:B], jnp.int32)
    reps = 2 if smoke else 3
    t_kernel = time_call(
        lambda: ops.paged_attention(q, kp, vp, bt, lengths), reps=reps)
    gather = jax.jit(functools.partial(ref.paged_attention_ref,
                                       kv_scale=None))
    t_gather = time_call(lambda: gather(q, kp, vp, bt, lengths), reps=reps)
    live = int(sum(-(-int(n) // page) * page for n in lengths))
    fl = 4.0 * H * D * float(sum(int(n) for n in lengths))
    rows = []
    for name, t, kv_bytes, live_bytes in (
            ("kernel_paged_attn", t_kernel,
             2 * live * KV * D * 4,            # each live page read once
             2 * page * KV * D * 4),           # one K+V tile resident
            ("kernel_paged_attn_gather", t_gather,
             2 * 3 * B * S * KV * D * 4,       # pool read + scratch w/r
             2 * B * S * KV * D * 4)):         # full gathered KV live
        rows.append({
            "bench": name, "shape": f"B{B}H{H}kv{KV}D{D}p{page}x{n_blocks}",
            "interpret_ms": t * 1e3,
            "tpu_t_compute_us": fl / PEAK_FLOPS * 1e6,
            "tpu_t_memory_us": kv_bytes / HBM_BW * 1e6,
            "bound": "memory",                 # decode attention always is
            "spatial_util": "",
            "peak_live_bytes": live_bytes,
        })
    return rows


def run(smoke: bool = False) -> List[Dict]:
    rows: List[Dict] = []
    shapes = [(512, 512, 512), (1024, 1024, 1024), (128, 4096, 128)]
    if smoke:
        shapes = [(256, 256, 256)]
    for (M, K, N) in shapes:
        block = (128, 128, 128)
        x = jax.random.normal(jax.random.key(0), (M, K), jnp.float32)
        w = jax.random.normal(jax.random.key(1), (K, N), jnp.float32)
        t = time_call(lambda: ops.matmul(x, w, block=block), reps=3)
        flops, hbm = _gemm_terms(M, K, N, block)
        rows.append({
            "bench": "kernel_gemm_os", "shape": f"{M}x{K}x{N}",
            "interpret_ms": t * 1e3,
            "tpu_t_compute_us": flops / PEAK_FLOPS * 1e6,
            "tpu_t_memory_us": hbm / HBM_BW * 1e6,
            "bound": "compute" if flops / PEAK_FLOPS > hbm / HBM_BW
                     else "memory",
            "spatial_util": spatial_utilization(M, K, N, block),
        })
    # quantized GEMM (int8 path, fused epilogue)
    xi = jax.random.randint(jax.random.key(2), (256, 1024), -128, 127,
                            jnp.int8)
    wi = jax.random.randint(jax.random.key(3), (1024, 256), -128, 127,
                            jnp.int8)
    t = time_call(lambda: ops.quant_matmul(xi, wi, 0.01), reps=3)
    flops, hbm = _gemm_terms(256, 1024, 256, (128, 128, 128), dtype_bytes=1)
    rows.append({
        "bench": "kernel_quant_gemm", "shape": "256x1024x256-int8",
        "interpret_ms": t * 1e3,
        "tpu_t_compute_us": flops / PEAK_FLOPS * 1e6,
        "tpu_t_memory_us": hbm / HBM_BW * 1e6,
        "bound": "fused-epilogue", "spatial_util": 1.0,
    })
    # attention
    B, S, H, KV, D = (1, 256, 8, 2, 64) if smoke else (1, 1024, 8, 2, 64)
    q = jax.random.normal(jax.random.key(4), (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.key(5), (B, S, KV, D), jnp.float32)
    v = jax.random.normal(jax.random.key(6), (B, S, KV, D), jnp.float32)
    t = time_call(lambda: ops.attention(q, k, v, bq=128, bk=128), reps=2)
    fl = 4.0 * B * H * S * S * D * 0.5          # causal half
    hbm = 2 * (B * S * H * D + 2 * B * S * KV * D) * 4
    rows.append({
        "bench": "kernel_mha", "shape": f"B{B}S{S}H{H}kv{KV}D{D}",
        "interpret_ms": t * 1e3,
        "tpu_t_compute_us": fl / PEAK_FLOPS * 1e6,
        "tpu_t_memory_us": hbm / HBM_BW * 1e6,
        "bound": "compute" if fl / PEAK_FLOPS > hbm / HBM_BW else "memory",
        "spatial_util": "",
    })
    # conv
    xc = jax.random.normal(jax.random.key(7), (1, 28, 28, 64), jnp.float32)
    wc = jax.random.normal(jax.random.key(8), (3, 3, 64, 128), jnp.float32)
    t = time_call(lambda: ops.conv2d(xc, wc, stride=1), reps=2)
    fl = 2.0 * 28 * 28 * 9 * 64 * 128
    rows.append({
        "bench": "kernel_conv_im2col", "shape": "28x28x64->128 3x3",
        "interpret_ms": t * 1e3,
        "tpu_t_compute_us": fl / PEAK_FLOPS * 1e6,
        "tpu_t_memory_us": "", "bound": "", "spatial_util": "",
    })
    rows.extend(_paged_attn_rows(smoke))
    return rows
