"""Fig. 6(b): temporal utilization, MGDP vs plain shared memory.

Paper claims: 76.99%-97.32% temporal utilization with MGDP; 2.12-2.94x
over the no-prefetch baseline. Includes cross-validation of the closed
form against the cycle-accurate event simulator.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import geomean
from repro.core import temporal, workloads


def run() -> List[Dict]:
    rows = []
    utils, gains = [], []
    for name, wl in workloads.all_workloads().items():
        r = temporal.temporal_report(wl)
        utils.append(r["util_mgdp"])
        gains.append(r["gain"])
        rows.append({
            "bench": "fig6b_temporal", "workload": name,
            "util_mgdp": r["util_mgdp"], "util_plain": r["util_plain"],
            "gain": r["gain"],
        })
    rows.append({"bench": "fig6b_temporal", "workload": "GEOMEAN",
                 "util_mgdp": geomean(utils), "util_plain": "",
                 "gain": geomean(gains)})
    rows.append({"bench": "fig6b_temporal", "workload": "PAPER_ANCHOR",
                 "util_mgdp": "0.7699-0.9732", "util_plain": "",
                 "gain": "2.12-2.94"})
    # closed form vs event sim (k_beats sweep)
    for k in (8, 32, 128):
        sim_m = temporal.simulate_tile(k, mgdp=True, n_tiles=16).util
        sim_p = temporal.simulate_tile(k, mgdp=False, n_tiles=16).util
        rows.append({
            "bench": "fig6b_simcheck", "workload": f"k_beats={k}",
            "util_mgdp": sim_m, "util_plain": sim_p,
            "gain": sim_m / max(sim_p, 1e-9),
        })
    return rows
