"""Fig. 1(c): provisioned on-chip memory, shared vs separated, for the
same ResNet50 tiling. Paper claim: shared uses ~50% less memory."""
from __future__ import annotations

from typing import Dict, List

from repro.core import tiling, workloads


def run() -> List[Dict]:
    rows = []
    for name, wl in workloads.all_workloads().items():
        r = tiling.memory_usage_report(wl)
        rows.append({
            "bench": "fig1c_memory", "workload": name,
            "shared_provisioned_kib": r["shared_provisioned_bytes"] / 1024,
            "separated_provisioned_kib":
                r["separated_provisioned_bytes"] / 1024,
            "saving_frac": r["saving_frac"],
        })
    rows.append({"bench": "fig1c_memory", "workload": "PAPER_ANCHOR",
                 "shared_provisioned_kib": "",
                 "separated_provisioned_kib": "",
                 "saving_frac": "~0.50 (ResNet50)"})
    return rows
