"""Table I: headline chip numbers from the calibrated model."""
from __future__ import annotations

from typing import Dict, List

from repro.core import simulator as sim


def run() -> List[Dict]:
    t = sim.table1()
    paper = {"macs": 512, "peak_tops": 0.82, "peak_tops_per_w": 1.60,
             "power_mw_min": 171, "power_mw_max": 981,
             "area_eff_tops_mm2": 1.25, "mem_kib": 128}
    rows = []
    for k, v in t.items():
        rows.append({"bench": "table1", "metric": k, "model": v,
                     "paper": paper.get(k, "")})
    return rows
