"""Fig. 4(c): BERT-Base MHA (1 head, token 64) data-access counts,
shared+PDMA (dynamic base pointers, on-the-fly K^T) vs separated buffers.
Paper claim: 14.3% fewer total accesses."""
from __future__ import annotations

from typing import Dict, List

from repro.core import pdma


def run() -> List[Dict]:
    r = pdma.mha_access_counts()
    rows = [
        {"bench": "fig4c_mha", "variant": "shared_pdma",
         "sram_accesses": r["shared"].sram, "dram_accesses": r["shared"].dram,
         "total": r["shared"].total, "saving_frac": ""},
        {"bench": "fig4c_mha", "variant": "separated(X resident)",
         "sram_accesses": r["separated"].sram,
         "dram_accesses": r["separated"].dram,
         "total": r["separated"].total, "saving_frac": r["saving_frac"]},
        {"bench": "fig4c_mha", "variant": "separated(X refetched)",
         "sram_accesses": r["separated_refetch"].sram,
         "dram_accesses": r["separated_refetch"].dram,
         "total": r["separated_refetch"].total,
         "saving_frac": r["saving_frac_refetch"]},
        {"bench": "fig4c_mha", "variant": "PAPER_ANCHOR",
         "sram_accesses": "", "dram_accesses": "", "total": "",
         "saving_frac": 0.143},
        {"bench": "fig4c_mha", "variant": "peak_arena",
         "sram_accesses": "", "dram_accesses": "",
         "total": r["peak_arena_bytes"],
         "saving_frac": f"cap={r['arena_capacity']}"},
    ]
    return rows
