"""Shared helpers for the benchmark harness: row formatting + timing."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List


def emit(rows: Iterable[Dict], header: bool = True) -> str:
    rows = list(rows)
    if not rows:
        return ""
    # union of keys across rows (insertion-ordered): suites may add
    # columns mid-stream (e.g. kernel_bench's peak_live_bytes)
    keys = list(dict.fromkeys(k for r in rows for k in r))
    out = []
    if header:
        out.append(",".join(keys))
    for r in rows:
        out.append(",".join(_fmt(r.get(k, "")) for k in keys))
    return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    s = str(v)
    if "," in s or '"' in s or "\n" in s:
        # RFC-4180 quoting: engine names like paged[kernel,tp2] and
        # skip-note cells embed commas; unquoted they shift every later
        # column, which broke machine consumers (benchmarks/check_csv.py)
        s = '"' + s.replace('"', '""') + '"'
    return s


def time_call(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call (after warmup, block_until_ready)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def geomean(xs: List[float]) -> float:
    import math
    xs = [x for x in xs if x > 0]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0
