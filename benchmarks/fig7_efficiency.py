"""Fig. 7(b)/(c)/(d): efficiency vs supply voltage, weight sparsity /
toggle rate, and GEMM size. Paper anchors: 1.60 TOPS/W peak @0.6 V;
efficiency falls with V while throughput rises; sparsity raises effective
efficiency; larger GEMMs (K especially) are more efficient."""
from __future__ import annotations

from typing import Dict, List

from repro.core import simulator as sim


def run() -> List[Dict]:
    rows: List[Dict] = []
    # (b) voltage sweep on the paper's 96^3 dense GEMM
    for v in (0.6, 0.7, 0.8, 0.9, 1.0):
        e = sim.gemm_efficiency(96, 96, 96, vdd=v)
        rows.append({"bench": "fig7b_voltage", "point": f"{v:.1f}V",
                     "tops": e["tops"], "tops_per_w": e["tops_per_w"],
                     "power_mw": e["power_mw"],
                     "freq_mhz": e["freq_mhz"]})
    rows.append({"bench": "fig7b_voltage", "point": "PAPER_ANCHOR",
                 "tops": "0.82 peak", "tops_per_w": "1.60 @0.6V",
                 "power_mw": "171-981", "freq_mhz": "300-800"})
    # (c) sparsity / toggle-rate
    for ws in (0.0, 0.25, 0.5, 0.75, 0.9):
        rows.append({"bench": "fig7c_sparsity", "point": f"ws={ws}",
                     "tops": "", "tops_per_w":
                         sim.sparsity_efficiency(96, 96, 96,
                                                 weight_sparsity=ws),
                     "power_mw": "", "freq_mhz": ""})
    for tr in (1.0, 0.6, 0.2):
        rows.append({"bench": "fig7c_sparsity", "point": f"tr={tr}",
                     "tops": "", "tops_per_w":
                         sim.sparsity_efficiency(96, 96, 96,
                                                 weight_sparsity=0.0,
                                                 toggle_rate=tr),
                     "power_mw": "", "freq_mhz": ""})
    # (d) GEMM size sweep: cubes (on-chip regime) + K-dim sweep
    for n in (32, 64, 96, 128):
        e = sim.gemm_efficiency(n, n, n)
        rows.append({"bench": "fig7d_size", "point": f"{n}^3",
                     "tops": e["tops"], "tops_per_w": e["tops_per_w"],
                     "power_mw": e["power_mw"], "freq_mhz": ""})
    for k in (96, 192, 384, 512):
        e = sim.gemm_efficiency(96, k, 96)
        rows.append({"bench": "fig7d_size", "point": f"96x{k}x96",
                     "tops": e["tops"], "tops_per_w": e["tops_per_w"],
                     "power_mw": e["power_mw"], "freq_mhz": ""})
    return rows
