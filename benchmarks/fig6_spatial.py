"""Fig. 6(a): spatial utilization, 3D (8x8x8) vs 2D (16x32), 8 workloads.

Paper claims: 69.71%-100% spatial utilization for Voltra; up to 2.0x
improvement over the 2D array (the GEMV-shaped cases hit exactly 2.0x).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import geomean
from repro.core import spatial, workloads


def run() -> List[Dict]:
    rows = []
    gains, utils = [], []
    for name, wl in workloads.all_workloads().items():
        r = spatial.spatial_report(wl)
        gains.append(r["gain"])
        utils.append(r["util_3d"])
        rows.append({
            "bench": "fig6a_spatial",
            "workload": name,
            "util_3d": r["util_3d"],
            "util_2d": r["util_2d"],
            "gain_vs_2d": r["gain"],
            "util_3d_cycleweighted": r["util_3d_cycle"],
        })
    rows.append({
        "bench": "fig6a_spatial", "workload": "GEOMEAN",
        "util_3d": geomean(utils), "util_2d": "",
        "gain_vs_2d": geomean(gains), "util_3d_cycleweighted": "",
    })
    rows.append({
        "bench": "fig6a_spatial", "workload": "PAPER_ANCHOR",
        "util_3d": "0.6971-1.0", "util_2d": "",
        "gain_vs_2d": "up to 2.0", "util_3d_cycleweighted": "",
    })
    # sensitivity: batch-1 decode (pure GEMV) shows where the 2.0x is won
    gemv = workloads.llama32_3b_decode(batch=1)
    r = spatial.spatial_report(gemv)
    rows.append({
        "bench": "fig6a_spatial", "workload": "llama_decode_b1(sens)",
        "util_3d": r["util_3d"], "util_2d": r["util_2d"],
        "gain_vs_2d": r["gain"], "util_3d_cycleweighted": r["util_3d_cycle"],
    })
    return rows
