"""Serving engine benchmark: paged (in-kernel vs dense-gather decode
attention) vs the seed dense-slot engine, plus the prefix-sharing,
speculative-decode and hybrid-stack scenarios.

Seven scenarios, all generated deterministically from ``--seed`` so the CI
bench-smoke CSV artifacts are comparable run-to-run:

**mixed** — a mixed-length request trace (every prompt a different length —
the production case the dense engine handles worst) replayed through three
engines on the same model/params: the dense-slot baseline, the paged
engine with the PR-1 per-layer ``pool[block_table]`` dense gather
(``attn_impl="gather"``), and the paged engine with the Pallas flash-
decode kernel that performs the block-table gather inside the kernel
(``attn_impl="kernel"``, the default). Reported per engine:

* ``decode_tok_s``  — decoded tokens / wall time spent inside decode
  steps (engine ``step_wall_s`` telemetry), measured WARM (every engine
  is pre-compiled over the trace's lengths/buckets first): the steady-
  state decode throughput a capacity planner cares about. On CPU the
  kernel runs in Pallas interpret mode, whose per-grid-step dispatch
  cost is the same order as these toy attention shapes — wall deltas
  between gather and kernel here are noise-bound; the structural win is
  ``attn_peak_live_bytes`` (see DESIGN.md "Paged attention")
* ``trace_tok_s``   — generated tokens / whole-trace wall (prefill +
  scheduling included)
* ``ttft_mean_s``   — mean time-to-first-token across requests
* ``prefill_traces``— distinct prefill compilations: once per LENGTH
  BUCKET for paged (mixed-grained-prefetch analogue), once per distinct
  prompt length for dense
* ``kv_util`` / ``peak_kv_tokens`` — live tokens over allocated page
  capacity at peak, vs the dense engine's static ``slots * max_len``
  reservation (the paper's dynamic-allocation utilization claim)
* ``attn_peak_live_bytes`` — peak live bytes of the per-layer decode-
  attention KV working set: the gather path materializes the full
  (B, n_blocks*page, KV, D) K and V scratch every layer; the kernel path
  keeps one (page, KV, D) K/V tile resident (the paper's separated-vs-
  shared memory access cost, measured at the serving level)

**shared-prefix** — every request opens with the same system prompt
(the "millions of users" overlap pattern); the paged[kernel] engine runs
WITHOUT and WITH the prefix cache (``runtime/prefix_cache.py``). Extra
columns: ``prefill_tokens`` (actually computed — the FLOPs proxy, since
prefill compute is linear in prefilled tokens for fixed model),
``prefill_saved_frac``, ``prefix_hit_rate`` / ``shared_token_frac``
(radix-tree telemetry), and ``peak_kv_tokens`` now reflects refcounted
page reuse. The ``prefix/noshare`` ratio row is the paper-style claim:
prefill compute and peak paging, sharing vs private.

**speculative** — templated/repetitive traffic (repeated prompt motifs —
the boilerplate pattern prompt-lookup drafting hits); the paged[kernel]
engine runs with ``spec_k=0`` (the T=1 baseline) and with ``--spec-k``
drafted tokens verified per multi-token step. Extra columns:
``decode_steps`` (each one streams the full weights + live pages once —
the memory-bound cost speculative decode amortizes),
``accepted_per_step`` (tokens emitted per request per verify step; the
baseline is 1.0 by construction) and ``accept_rate``. The ``specK/T=1``
ratio row is the claim: identical greedy tokens in fewer weight/KV
streams, i.e. decode arithmetic intensity multiplied by
``accepted_per_step`` at unchanged page traffic.

**sampling** — decode policies (ISSUE 9): the templated trace replayed
greedy (plain / n-gram spec / draft-model spec — all three must emit
IDENTICAL tokens; ``tokens_match_greedy`` is CI-gated by
``benchmarks/check_csv.py``) and sampled (temperature + top-p, per-request
``SamplingParams``, plain and rejection-sampled speculative — their
exactness claim is distributional, tested in tests/test_sampling.py, so
the match cell stays empty). Extra columns: ``accept_rate`` /
``drafter_kind`` for spec rows, ``sampled_tokens`` and the
``step_traces`` / ``spec_traces`` retrace telemetry (policies are traced-
program OPERANDS — greedy and sampled requests share one compilation).
The draft-model rows self-draft (target model == draft model, both
smoke-sized), exercising the drafter's incremental paged-KV sync without
a second arch's weights.

**hybrid** — a griffin-style hybrid stack (``recurrentgemma-9b`` smoke:
rglru + local_attn sliding window) with prompts LONGER than the window,
replayed through the dense baseline and the paged engine under both attn
impls. This is ISSUE 5's claim: windowed layers get paged ring buffers
whose pages are *recycled* as they slide out of the window
(``PageAllocator.release_prefix``), so ``peak_kv_tokens`` stays O(window)
per request while the dense engine reserves ``slots * max_len``; recurrent
layers ride along in fixed-size state slots. Extra columns:
``win_recycled_pages`` (pages slid out and freed), ``win_page_bound``
(ceil(window/page) + 1 — the per-request live-page ceiling the engine
enforces), and the ``paged/dense`` ratio row's ``peak_kv_tokens`` is the
headline (window / max_len-bound memory, identical greedy tokens).

**sharded** — the mixed trace through the paged[kernel] engine at
model = 1/2/4 tensor-parallel shards (``parallel/tp.py`` over
``launch/mesh.make_host_mesh`` meshes; on CPU CI the devices come from
``XLA_FLAGS=--xla_force_host_platform_device_count=4``). Extra columns:
``model_shards`` / ``sharded_axes``, ``peak_pages_per_shard`` (equals the
allocator peak — block tables are replicated, each shard holds its
KV-head slice of the same page set), ``pool_bytes_per_shard`` (what TP
actually divides) and ``tokens_match_tp1`` (every shard count must emit
the single-shard engine's exact greedy tokens). Shard counts the backend
cannot fold are emitted as skip-note rows, not dropped.

**oversubscribe** — working set >> device pool (ISSUE 7): the mixed trace
through an unconstrained paged engine, then through pools capped at ~40%
of the trace's KV footprint, evict-only vs host-tiered
(``runtime/host_tier.py``). Every capped row must report
``tokens_match_unconstrained=1`` — a capped pool may change WHEN tokens
are computed, never WHICH — and CI's ``benchmarks/check_csv.py`` gate
fails the build on any other value. The tiered rows' headline is
``reprefill_tokens_saved`` (prefill compute the evict-only engine
re-spent on preemption-resume that swap-in did not) plus the streamer
telemetry: ``prefetch_hit_rate`` / ``copy_stall_ticks`` /
``host_bytes_peak``. Prefix-cache and hybrid pairs ride along so all
three demotion sources (idle radix nodes, preempted requests incl.
recurrent state, slid-out window pages) run inside the timed replay.

Every scenario row additionally carries the unified latency/utilization
columns from ``engine.metrics()`` (ISSUE 8): ``ttft_p50`` / ``ttft_p95``
(arrival-to-first-token, queue wait included), ``tpot_p50`` / ``tpot_p95``
(per-token decode latency) and ``temporal_util`` (device-step wall over
decode-tick wall — the serving analogue of the paper's Fig. 6 temporal-
utilization breakdown). ``--trace-out trace.json`` exports the whole run
as Chrome Trace Event JSON, loadable in Perfetto.

  PYTHONPATH=src python -m benchmarks.serve_bench [--arch qwen2.5-3b]
      [--seed 0] [--trace-out trace.json]
      [--scenario mixed|shared-prefix|speculative|sampling|hybrid|sharded|
       oversubscribe|all]

(the hybrid scenario pins its own arch — recurrentgemma-9b smoke — since
it exists to exercise the windowed/recurrent block kinds.)
"""
from __future__ import annotations

import argparse
import random
import sys
import time
from typing import Dict, List, Optional

import jax

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.models import api
from repro.runtime.scheduler import Scheduler
from repro.runtime.serving import (DenseServingEngine, PagedServingEngine,
                                   Request)
from repro.runtime.trace import NULL_TRACER, Tracer, set_default_tracer


def _trace(cfg, n_requests: int, max_new: int, seed: int) -> List[Request]:
    """Mixed-length trace: all prompt lengths distinct (3, 8, 13, ...),
    spanning several power-of-two buckets; token ids drawn from the seeded
    rng so the trace is identical for identical seeds."""
    rng = random.Random(seed)
    return [Request(rid=i,
                    prompt=[rng.randrange(cfg.vocab)
                            for _ in range(3 + 5 * i)],
                    max_new=max_new)
            for i in range(n_requests)]


def _shared_trace(cfg, n_requests: int, max_new: int, seed: int,
                  sys_len: int) -> List[Request]:
    """Shared-system-prompt trace: every request = the same ``sys_len``
    token system prompt + a short per-request tail (deterministic in
    ``seed``)."""
    rng = random.Random(seed)
    sys_prompt = [rng.randrange(cfg.vocab) for _ in range(sys_len)]
    return [Request(rid=i,
                    prompt=sys_prompt + [rng.randrange(cfg.vocab)
                                         for _ in range(2 + i % 5)],
                    max_new=max_new)
            for i in range(n_requests)]


def _spec_trace(cfg, n_requests: int, max_new: int, seed: int,
                motif_len: int = 6, reps: int = 4) -> List[Request]:
    """Templated/repetitive trace: every prompt is a repeated motif (the
    boilerplate / few-shot / structured-output pattern prompt-lookup
    drafting feeds on) behind a short per-request salt, so requests differ
    but their contexts — and the repetitive spans the model then emits —
    give the n-gram drafter something to hit."""
    rng = random.Random(seed)
    motif = [rng.randrange(cfg.vocab) for _ in range(motif_len)]
    return [Request(rid=i,
                    prompt=[rng.randrange(cfg.vocab)
                            for _ in range(i % 3)] + motif * reps,
                    max_new=max_new)
            for i in range(n_requests)]


def _warm(engine, mk_trace) -> None:
    """Compile-warm the engine: replay the trace's prompt lengths (covers
    every prefill trace/bucket for dense AND paged) with max_new=2 for a
    couple of decode steps, so the timed replay measures steady-state
    serving rather than jit tracing — the number a capacity planner
    wants is the warm one."""
    sched = Scheduler(engine)
    for r in mk_trace(2):
        sched.add(r)
    sched.drain(max_steps=1000)
    # warmup compiled + ran; zero the telemetry the timed replay reports.
    # One call owns the whole reset contract (engine counters, latency
    # stamps, pool high-water marks, prefix hit counters, tier transfer
    # rates) so benches can't drift out of sync with new subsystems —
    # warmed STATE (radix tree contents, demoted host nodes, jit caches)
    # survives; only the counters the replay reports are zeroed.
    engine.reset_metrics()


def _attn_peak_live_bytes(cfg, engine) -> int:
    """Peak live bytes of the per-layer decode-attention KV working set."""
    kv, hd = cfg.kv_heads, cfg.resolved_head_dim
    if isinstance(engine, PagedServingEngine) \
            and engine.attn_impl == "kernel":
        # one K + one V page tile resident per kernel program, in the
        # pool's storage dtype (int8 pools dequantize inside the kernel)
        itemsize = 1 if cfg.kv_cache_dtype == "int8" else 2
        return 2 * engine.page_size * kv * hd * itemsize
    # dense lanes / dense gather: the whole (B, max_len, KV, D) K and V,
    # materialized DEQUANTIZED to the 2-byte activation dtype
    # (layers.kv_dequant) regardless of the cache storage dtype. A dense
    # engine whose attention is ALL sliding-window (griffin-style: no
    # full-attention kinds) only ever holds window-sized rings, so its
    # working set is window-bounded; any full-attention layer in the
    # pattern holds max_len lanes (the paged gather baseline always
    # materializes the full table length).
    seq = engine.max_len
    if not isinstance(engine, PagedServingEngine) and cfg.hybrid is not None:
        from repro.models.api import PAGEABLE_KINDS
        if not set(cfg.hybrid.pattern) & set(PAGEABLE_KINDS):
            seq = min(seq, cfg.hybrid.window)
    return 2 * engine.slots * seq * kv * hd * 2


def _drive(engine, reqs: List[Request], max_steps: int, cfg,
           name: Optional[str] = None) -> Dict:
    sched = Scheduler(engine)
    for r in reqs:
        sched.add(r)
    t0 = time.perf_counter()
    sched.drain(max_steps=max_steps, on_exhaust="warn")
    wall = time.perf_counter() - t0
    done = [r for r in reqs if r.done]
    toks = sum(len(r.generated) for r in done)
    if name is None:
        name = type(engine).__name__
        if isinstance(engine, PagedServingEngine):
            name += f"[{engine.attn_impl}]"
    # latency percentiles + temporal utilization come from the unified
    # metrics surface (arrival stamped at Scheduler.add, so TTFT includes
    # queue wait — the number a latency SLO is written against)
    m = engine.metrics()
    row = {
        "engine": name,
        "requests_done": len(done),
        "tokens": toks,
        "wall_s": wall,
        "decode_tok_s": engine.decoded_tokens / engine.step_wall_s
        if engine.step_wall_s else 0.0,
        "trace_tok_s": toks / wall if wall else 0.0,
        "ttft_mean_s": m["latency.ttft_mean_s"],
        "ttft_p50": m["latency.ttft_p50_s"],
        "ttft_p95": m["latency.ttft_p95_s"],
        "tpot_p50": m["latency.tpot_p50_s"],
        "tpot_p95": m["latency.tpot_p95_s"],
        "temporal_util": m["util.temporal"],
        "prefill_traces": engine.prefill_traces,
        "sched_exhausted": int(sched.exhausted),
    }
    if isinstance(engine, PagedServingEngine):
        st = engine.pool_stats()
        row["peak_kv_tokens"] = st.peak_pages * st.page_size
        row["kv_util_vs_dense"] = (st.peak_pages * st.page_size
                                   / st.dense_equiv_tokens)
    else:
        row["peak_kv_tokens"] = engine.slots * engine.max_len
        row["kv_util_vs_dense"] = 1.0
    row["attn_peak_live_bytes"] = _attn_peak_live_bytes(cfg, engine)
    return row


def _run_mixed(cfg, params, slots, max_len, n_requests, max_new,
               seed) -> List[Dict]:
    def mk(new):
        return _trace(cfg, n_requests, new, seed)

    rows = []
    dense = DenseServingEngine(cfg, params, slots=slots, max_len=max_len)
    _warm(dense, mk)
    rows.append(_drive(dense, mk(max_new), 4000, cfg))
    for impl in ("gather", "kernel"):
        paged = PagedServingEngine(cfg, params, slots=slots,
                                   max_len=max_len, attn_impl=impl)
        _warm(paged, mk)
        rows.append(_drive(paged, mk(max_new), 4000, cfg))
    d, g, k = rows[0], rows[1], rows[2]

    def ratio_row(name: str, base: Dict) -> Dict:
        """Summary row: kernel engine vs `base` (counts as deltas,
        times/bytes as base/kernel speedup or kernel/base footprint)."""
        return {
            "engine": name,
            "requests_done": k["requests_done"] - base["requests_done"],
            "tokens": k["tokens"] - base["tokens"],
            "wall_s": base["wall_s"] / k["wall_s"],
            "decode_tok_s": k["decode_tok_s"] / base["decode_tok_s"],
            "trace_tok_s": k["trace_tok_s"] / base["trace_tok_s"],
            "ttft_mean_s": base["ttft_mean_s"] / k["ttft_mean_s"]
            if k["ttft_mean_s"] else 0.0,
            "prefill_traces": k["prefill_traces"] - base["prefill_traces"],
            "peak_kv_tokens": k["peak_kv_tokens"] - base["peak_kv_tokens"],
            "kv_util_vs_dense": k["kv_util_vs_dense"],
            "attn_peak_live_bytes": k["attn_peak_live_bytes"]
            / base["attn_peak_live_bytes"],
        }

    rows.append(ratio_row("kernel/gather", g))
    rows.append(ratio_row("kernel/dense", d))
    return rows


def _run_shared_prefix(cfg, params, slots, max_len, n_requests, max_new,
                       seed, sys_len) -> List[Dict]:
    def mk(new):
        return _shared_trace(cfg, n_requests, new, seed, sys_len)

    rows = []
    for share, name in ((False, "paged[kernel,noshare]"),
                        (True, "paged[kernel,prefix]")):
        eng = PagedServingEngine(cfg, params, slots=slots, max_len=max_len,
                                 attn_impl="kernel", prefix_cache=share)
        _warm(eng, mk)
        row = _drive(eng, mk(max_new), 4000, cfg, name=name)
        ps = eng.prefix_stats()
        row["prefill_tokens"] = int(ps["prefilled_tokens"])
        row["prefill_saved_frac"] = ps["prefill_saved_frac"]
        row["prefix_hit_rate"] = ps.get("hit_rate", 0.0)
        row["shared_token_frac"] = ps.get("shared_token_frac", 0.0)
        row["cow_copies"] = int(ps["cow_copies"])
        rows.append(row)
    base, pref = rows
    rows.append({
        "engine": "prefix/noshare",
        "requests_done": pref["requests_done"] - base["requests_done"],
        "tokens": pref["tokens"] - base["tokens"],
        "wall_s": base["wall_s"] / pref["wall_s"] if pref["wall_s"] else 0.0,
        "trace_tok_s": pref["trace_tok_s"] / base["trace_tok_s"]
        if base["trace_tok_s"] else 0.0,
        "ttft_mean_s": base["ttft_mean_s"] / pref["ttft_mean_s"]
        if pref["ttft_mean_s"] else 0.0,
        # the two headline savings: prefill compute (token-linear FLOPs
        # proxy) and peak physical paging, sharing vs no sharing
        "prefill_tokens": pref["prefill_tokens"] - base["prefill_tokens"],
        "prefill_saved_frac": 1.0 - (pref["prefill_tokens"]
                                     / base["prefill_tokens"])
        if base["prefill_tokens"] else 0.0,
        "peak_kv_tokens": pref["peak_kv_tokens"] - base["peak_kv_tokens"],
        "kv_util_vs_dense": pref["kv_util_vs_dense"],
        "prefix_hit_rate": pref["prefix_hit_rate"],
        "shared_token_frac": pref["shared_token_frac"],
    })
    return rows


def _run_speculative(cfg, params, slots, max_len, n_requests, max_new,
                     seed, spec_k) -> List[Dict]:
    def mk(new):
        return _spec_trace(cfg, n_requests, new, seed)

    rows = []
    for k, name in ((0, "paged[kernel,T=1]"),
                    (spec_k, f"paged[kernel,spec{spec_k}]")):
        eng = PagedServingEngine(cfg, params, slots=slots, max_len=max_len,
                                 attn_impl="kernel", spec_k=k)
        _warm(eng, mk)
        row = _drive(eng, mk(max_new), 4000, cfg, name=name)
        ss = eng.spec_stats()
        row["decode_steps"] = eng.decode_steps
        row["accepted_per_step"] = ss["accepted_per_step"]
        row["accept_rate"] = ss["accept_rate"]
        row["spec_drafted"] = int(ss["spec_drafted"])
        row["spec_accepted"] = int(ss["spec_accepted"])
        rows.append(row)
    base, spec = rows
    rows.append({
        "engine": f"spec{spec_k}/T=1",
        "requests_done": spec["requests_done"] - base["requests_done"],
        "tokens": spec["tokens"] - base["tokens"],
        "wall_s": base["wall_s"] / spec["wall_s"] if spec["wall_s"] else 0.0,
        "decode_tok_s": spec["decode_tok_s"] / base["decode_tok_s"]
        if base["decode_tok_s"] else 0.0,
        "trace_tok_s": spec["trace_tok_s"] / base["trace_tok_s"]
        if base["trace_tok_s"] else 0.0,
        # the headline pair: the SAME tokens in fewer verify steps (each
        # step = one full weight + live-page stream), i.e. arithmetic
        # intensity up by accepted_per_step at unchanged page traffic
        "decode_steps": spec["decode_steps"] - base["decode_steps"],
        "accepted_per_step": spec["accepted_per_step"],
        "accept_rate": spec["accept_rate"],
    })
    return rows


def _run_sampling(cfg, params, slots, max_len, n_requests, max_new,
                  seed, spec_k) -> List[Dict]:
    """Decode-policy rows (ISSUE 9) over the templated trace: three
    greedy engines (plain, n-gram spec, draft-model spec) that must all
    emit IDENTICAL tokens (``tokens_match_greedy`` — the CI-gated
    exactness claim), then the same three under a per-request sampled
    policy (temperature 0.9, top-p 0.95) where the speculative rows'
    claim is the acceptance rate at unchanged output DISTRIBUTION (the
    chi-square suite in tests/test_sampling.py; the match cell stays
    empty — token equality is not the sampled contract)."""
    from repro.runtime.drafter import DraftModelDrafter
    from repro.runtime.sampling import SamplingParams

    sampled = SamplingParams(temperature=0.9, top_p=0.95, seed=seed)

    def mk(new, pol=None):
        reqs = _spec_trace(cfg, n_requests, new, seed)
        for r in reqs:
            r.params = pol
        return reqs

    def draft():
        # self-draft: the target model doubles as the draft model (both
        # smoke-sized) — deterministic, so greedy rows stay exact, and
        # the drafter's incremental paged-KV sync runs for real
        return DraftModelDrafter(cfg, params, max_len=max_len)

    rows: List[Dict] = []
    greedy_toks = None
    for name, kw, pol in (
            ("paged[kernel,greedy]", {}, None),
            (f"paged[kernel,spec{spec_k},greedy]", {"spec_k": spec_k},
             None),
            (f"paged[kernel,draft{spec_k},greedy]",
             {"spec_k": spec_k, "drafter": draft()}, None),
            ("paged[kernel,sampled]", {}, sampled),
            (f"paged[kernel,spec{spec_k},sampled]", {"spec_k": spec_k},
             sampled),
            (f"paged[kernel,draft{spec_k},sampled]",
             {"spec_k": spec_k, "drafter": draft()}, sampled)):
        eng = PagedServingEngine(cfg, params, slots=slots, max_len=max_len,
                                 attn_impl="kernel", **kw)
        _warm(eng, lambda n, _p=pol: mk(n, _p))
        reqs = mk(max_new, pol)
        row = _drive(eng, reqs, 4000, cfg, name=name)
        m = eng.metrics()
        row["sampled_tokens"] = int(m["sampling.sampled_tokens"])
        # retrace telemetry: ONE step trace (and one spec trace when
        # speculative) no matter the greedy/sampled request mix —
        # policies ride in as operands, never as trace constants
        row["step_traces"] = int(m["sampling.step_traces"])
        row["spec_traces"] = int(m["sampling.spec_traces"])
        if kw.get("spec_k"):
            ss = eng.spec_stats()
            row["accept_rate"] = ss["accept_rate"]
            row["accepted_per_step"] = ss["accepted_per_step"]
            row["drafter_kind"] = ss["drafter"]
        if pol is None:
            toks = [list(r.generated) for r in reqs]
            if greedy_toks is None:
                greedy_toks = toks
            row["tokens_match_greedy"] = int(toks == greedy_toks)
        rows.append(row)
    return rows


def _hybrid_trace(cfg, n_requests: int, max_new: int, seed: int,
                  window: int) -> List[Request]:
    """Prompts straddling the attention window (some shorter, most
    longer), so admission, recycling and the window-boundary masking all
    run inside the timed replay."""
    rng = random.Random(seed)
    return [Request(rid=i,
                    prompt=[rng.randrange(cfg.vocab)
                            for _ in range(window // 2 + (5 * i) % (2 * window))],
                    max_new=max_new)
            for i in range(n_requests)]


def _run_hybrid(slots, max_len, n_requests, max_new, seed) -> List[Dict]:
    cfg = get_smoke_config("recurrentgemma-9b")
    params = api.init_params(cfg, jax.random.key(0))
    window = cfg.hybrid.window

    def mk(new):
        return _hybrid_trace(cfg, n_requests, new, seed, window)

    rows = []
    dense = DenseServingEngine(cfg, params, slots=slots, max_len=max_len)
    _warm(dense, mk)
    rows.append(_drive(dense, mk(max_new), 4000, cfg,
                       name="dense[hybrid]"))
    for impl in ("gather", "kernel"):
        paged = PagedServingEngine(cfg, params, slots=slots,
                                   max_len=max_len, attn_impl=impl)
        _warm(paged, mk)
        row = _drive(paged, mk(max_new), 4000, cfg,
                     name=f"paged[{impl},hybrid]")
        row["win_recycled_pages"] = paged.win_recycled_pages
        row["win_page_bound"] = paged.win_pages_bound(max_len)
        rows.append(row)
    d, k = rows[0], rows[2]
    rows.append({
        "engine": "paged/dense[hybrid]",
        "requests_done": k["requests_done"] - d["requests_done"],
        "tokens": k["tokens"] - d["tokens"],
        "wall_s": d["wall_s"] / k["wall_s"] if k["wall_s"] else 0.0,
        "decode_tok_s": k["decode_tok_s"] / d["decode_tok_s"]
        if d["decode_tok_s"] else 0.0,
        "trace_tok_s": k["trace_tok_s"] / d["trace_tok_s"]
        if d["trace_tok_s"] else 0.0,
        # the headline: peak physical KV, O(window)-recycled pages vs the
        # dense engine's slots * max_len reservation — same greedy tokens
        "peak_kv_tokens": k["peak_kv_tokens"] - d["peak_kv_tokens"],
        "kv_util_vs_dense": k["kv_util_vs_dense"],
        "win_recycled_pages": k["win_recycled_pages"],
        "win_page_bound": k["win_page_bound"],
    })
    return rows


def _run_sharded(cfg, params, slots, max_len, n_requests, max_new,
                 seed) -> List[Dict]:
    """Tensor-parallel scaling rows (ISSUE 6): the same mixed trace
    through the paged[kernel] engine at model=1/2/4 shards, one
    ``("data","model")`` mesh per shard count over the first ``s`` visible
    devices (single-shard = no mesh — the baseline every sharded row must
    match token-for-token). Shard counts the backend can't fold (fewer
    devices than shards — e.g. a CI leg without the forced-host-device
    flag) are skipped with a note row, NOT silently dropped: an empty
    scaling table must say why. Per-shard columns come from
    ``engine.shard_stats()``: pages are allocated logically and block
    tables are replicated, so ``peak_pages_per_shard`` equals the
    allocator's peak while ``pool_bytes_per_shard`` is what tensor
    parallelism actually divides."""
    from repro.launch.mesh import make_host_mesh

    def mk(new):
        return _trace(cfg, n_requests, new, seed)

    n_dev = len(jax.devices())
    rows: List[Dict] = []
    baseline: Optional[List[List[int]]] = None
    for s in (1, 2, 4):
        if s > n_dev:
            rows.append({"engine": f"paged[kernel,tp{s}]",
                         "model_shards": s, "skipped":
                         f"needs {s} devices, have {n_dev} "
                         f"(XLA_FLAGS=--xla_force_host_platform_"
                         f"device_count=4)"})
            continue
        mesh = make_host_mesh(model=s, devices=jax.devices()[:s]) \
            if s > 1 else None
        eng = PagedServingEngine(cfg, params, slots=slots, max_len=max_len,
                                 attn_impl="kernel", mesh=mesh)
        _warm(eng, mk)
        reqs = mk(max_new)
        row = _drive(eng, reqs, 4000, cfg, name=f"paged[kernel,tp{s}]")
        toks = [r.generated for r in reqs]
        if baseline is None:
            baseline = toks
        st = eng.shard_stats()
        row["model_shards"] = int(st["model_shards"])
        row["sharded_axes"] = st["sharded_axes"] or "-"
        row["peak_pages_per_shard"] = int(st["peak_pages_per_shard"])
        row["pool_bytes_per_shard"] = int(st["pool_bytes_per_shard"])
        # the contract the scaling table rides on: every shard count
        # emits the SAME greedy tokens — a row that didn't is not a
        # data point, it's a bug, and the CSV must say so
        row["tokens_match_tp1"] = int(toks == baseline)
        rows.append(row)
    return rows


def _pool_cap(reqs: List[Request], max_len: int, page_size: int,
              frac: float = 0.4) -> int:
    """Device-pool cap (in pages) for the oversubscribe scenario: ``frac``
    of the trace's total worst-case KV footprint, floored at one page above
    the largest single request (below that the engine rightly rejects the
    request as infeasible rather than thrashing)."""
    need = [-(-min(len(r.prompt) + r.max_new, max_len) // page_size)
            for r in reqs]
    return max(int(sum(need) * frac), max(need) + 1)


def _run_oversubscribe(cfg, params, slots, max_len, n_requests, max_new,
                       seed, sys_len) -> List[Dict]:
    """Working set >> device pool (ISSUE 7): the mixed trace replayed
    through an unconstrained paged engine, then through engines whose pool
    is capped at ~40% of the trace's KV footprint — once with eviction-only
    preemption (resume = destructive re-prefill) and once with the host
    tier on (resume = swap-in from host RAM). Every capped row must emit
    the unconstrained engine's exact greedy tokens
    (``tokens_match_unconstrained`` — CI's check_csv gate fails the build
    otherwise); the tiered row's claim is ``reprefill_tokens_saved``:
    prefill compute the evict-only engine re-spent that the tier's
    promote path did not. A prefix-cache pair (shared-prefix trace, radix
    nodes demote to host instead of LRU-evicting and promote on hit) and a
    hybrid pair (recurrentgemma: recurrent STATE swaps with the pages)
    ride along so every demotion source is exercised."""
    rows: List[Dict] = []

    def mk(new):
        return _trace(cfg, n_requests, new, seed)

    for impl in ("gather", "kernel"):
        base = PagedServingEngine(cfg, params, slots=slots, max_len=max_len,
                                  attn_impl=impl)
        cap = _pool_cap(mk(max_new), max_len, base.page_size)
        _warm(base, mk)
        reqs = mk(max_new)
        row = _drive(base, reqs, 8000, cfg, name=f"paged[{impl},uncapped]")
        row["pool_pages"] = base.alloc.num_pages
        base_toks = [list(r.generated) for r in reqs]
        base_prefilled = base.prefilled_tokens
        rows.append(row)
        evict_reprefill = 0
        for tier, name in ((False, f"paged[{impl},evict@cap]"),
                           (True, f"paged[{impl},tiered@cap]")):
            eng = PagedServingEngine(cfg, params, slots=slots,
                                     max_len=max_len, attn_impl=impl,
                                     num_pages=cap, host_tier=tier)
            _warm(eng, mk)
            reqs = mk(max_new)
            row = _drive(eng, reqs, 8000, cfg, name=name)
            row["pool_pages"] = cap
            row["preemptions"] = sum(r.preemptions for r in reqs)
            # the contract the whole scenario rides on: a capped pool may
            # change WHEN tokens are computed, never WHICH tokens
            row["tokens_match_unconstrained"] = \
                int([list(r.generated) for r in reqs] == base_toks)
            # prefill compute re-spent on preemption-resume (0 for the
            # unconstrained engine by construction)
            row["reprefill_tokens"] = eng.prefilled_tokens - base_prefilled
            if not tier:
                evict_reprefill = row["reprefill_tokens"]
            else:
                ts = eng.tier_stats()
                row["reprefill_tokens_saved"] = \
                    evict_reprefill - row["reprefill_tokens"]
                for k in ("swap_outs", "swap_ins", "demoted_pages",
                          "promoted_pages", "prefetch_hit_rate",
                          "copy_stall_ticks", "host_bytes_peak"):
                    row[k] = ts[k]
            rows.append(row)

    # prefix-cache pair: idle radix nodes demote to host before LRU
    # eviction; radix hits on host-resident nodes promote (prefetched a
    # tick early) instead of re-prefilling the shared system prompt
    def mk_shared(new):
        return _shared_trace(cfg, n_requests, new, seed, sys_len)

    base = PagedServingEngine(cfg, params, slots=slots, max_len=max_len,
                              attn_impl="kernel", prefix_cache=True)
    cap = _pool_cap(mk_shared(max_new), max_len, base.page_size)
    _warm(base, mk_shared)
    reqs = mk_shared(max_new)
    row = _drive(base, reqs, 8000, cfg, name="paged[kernel,prefix,uncapped]")
    row["pool_pages"] = base.alloc.num_pages
    base_toks = [list(r.generated) for r in reqs]
    rows.append(row)
    eng = PagedServingEngine(cfg, params, slots=slots, max_len=max_len,
                             attn_impl="kernel", prefix_cache=True,
                             num_pages=cap, host_tier=True)
    _warm(eng, mk_shared)
    reqs = mk_shared(max_new)
    row = _drive(eng, reqs, 8000, cfg, name="paged[kernel,prefix,tiered@cap]")
    row["pool_pages"] = cap
    row["preemptions"] = sum(r.preemptions for r in reqs)
    row["tokens_match_unconstrained"] = \
        int([list(r.generated) for r in reqs] == base_toks)
    ts = eng.tier_stats()
    for k in ("cache_demotions", "cache_promotions", "prefetch_hit_rate",
              "copy_stall_ticks", "host_bytes_peak"):
        row[k] = ts[k]
    rows.append(row)

    # hybrid pair: a preempted recurrentgemma request swaps its recurrent
    # state slots AND its window pages to host — resume restores both
    # (no re-prefill; PR 5 resumed these by re-prefilling)
    hcfg = get_smoke_config("recurrentgemma-9b")
    hparams = api.init_params(hcfg, jax.random.key(0))
    hn, hnew = max(4, n_requests // 2), max(max_new, 24)

    def mk_hybrid(new):
        return _hybrid_trace(hcfg, hn, new, seed, hcfg.hybrid.window)

    base = PagedServingEngine(hcfg, hparams, slots=slots, max_len=max_len,
                              attn_impl="gather")
    cap = _pool_cap(mk_hybrid(hnew), max_len, base.page_size)
    _warm(base, mk_hybrid)
    reqs = mk_hybrid(hnew)
    row = _drive(base, reqs, 8000, hcfg, name="paged[hybrid,uncapped]")
    row["pool_pages"] = base.alloc.num_pages
    base_toks = [list(r.generated) for r in reqs]
    rows.append(row)
    eng = PagedServingEngine(hcfg, hparams, slots=slots, max_len=max_len,
                             attn_impl="gather", num_pages=cap,
                             host_tier=True)
    _warm(eng, mk_hybrid)
    reqs = mk_hybrid(hnew)
    row = _drive(eng, reqs, 8000, hcfg, name="paged[hybrid,tiered@cap]")
    row["pool_pages"] = cap
    row["preemptions"] = sum(r.preemptions for r in reqs)
    row["tokens_match_unconstrained"] = \
        int([list(r.generated) for r in reqs] == base_toks)
    ts = eng.tier_stats()
    for k in ("swap_outs", "swap_ins", "win_archived_pages",
              "prefetch_hit_rate", "copy_stall_ticks", "host_bytes_peak"):
        row[k] = ts[k]
    rows.append(row)
    return rows


def run(arch: str = "qwen2.5-3b", slots: int = 4, max_len: int = 128,
        n_requests: int = 12, max_new: int = 8, smoke: bool = False,
        seed: int = 0, scenario: str = "all",
        sys_len: int = 48, spec_k: int = 4,
        trace_out: Optional[str] = None) -> List[Dict]:
    if smoke:       # decode-heavy but small: seconds, not minutes, with
        # enough steps that decode_tok_s isn't measuring scheduler noise
        slots, max_len, n_requests, max_new = 2, 128, 4, 24
        sys_len = 24
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    # --trace-out: install a process-default tracer so EVERY engine the
    # scenarios construct (they build their own) records into one timeline;
    # exported as Chrome Trace Event JSON (open in Perfetto / about:tracing)
    tracer = Tracer(enabled=True) if trace_out else None
    if tracer is not None:
        set_default_tracer(tracer)
    try:
        rows: List[Dict] = []
        if scenario in ("mixed", "all"):
            rows += _run_mixed(cfg, params, slots, max_len, n_requests,
                               max_new, seed)
        if scenario in ("shared-prefix", "all"):
            rows += _run_shared_prefix(cfg, params, slots, max_len,
                                       n_requests, max_new, seed, sys_len)
        if scenario in ("speculative", "all"):
            # speculative decode is a decode-tail story (every verify step
            # amortizes one full weight+page stream): give it a decode-heavy
            # trace even when the other scenarios run short ones
            rows += _run_speculative(cfg, params, slots, max_len,
                                     n_requests, max(max_new, 24), seed,
                                     spec_k)
        if scenario in ("sampling", "all"):
            # decode policies ride the templated trace too: the greedy
            # spec rows must match the greedy baseline token-for-token,
            # and a decode-heavy tail gives the sampled rows real
            # acceptance statistics
            rows += _run_sampling(cfg, params, slots, max_len, n_requests,
                                  max(max_new, 24), seed, spec_k)
        if scenario in ("hybrid", "all"):
            # windowed/recurrent stacks pin their own arch (recurrentgemma
            # smoke) and a decode tail long enough to slide past the window
            rows += _run_hybrid(slots, max_len, max(4, n_requests // 2),
                                max(max_new, 24), seed)
        if scenario in ("sharded", "all"):
            rows += _run_sharded(cfg, params, slots, max_len, n_requests,
                                 max_new, seed)
        if scenario in ("oversubscribe", "all"):
            # host-tier oversubscription is a preemption story: decode tails
            # long enough that capped pools MUST preempt mid-generation
            rows += _run_oversubscribe(cfg, params, slots, max_len,
                                       n_requests, max(max_new, 24), seed,
                                       sys_len)
    finally:
        if tracer is not None:
            set_default_tracer(NULL_TRACER)
            tracer.export(trace_out)
            print(f"# wrote {trace_out}: {len(tracer.events())} events "
                  f"({tracer.dropped_events} dropped)", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace-generation seed (same seed -> same trace, "
                         "so CI CSV artifacts are comparable run-to-run)")
    ap.add_argument("--scenario",
                    choices=["mixed", "shared-prefix", "speculative",
                             "sampling", "hybrid", "sharded",
                             "oversubscribe", "all"],
                    default="all")
    ap.add_argument("--sys-len", type=int, default=48,
                    help="shared system-prompt length for shared-prefix")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify step for speculative")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (seconds): CI per-PR regression signal")
    ap.add_argument("--trace-out", default=None, metavar="TRACE.JSON",
                    help="export a Chrome Trace Event JSON of the whole "
                         "run (open in Perfetto / about:tracing; validate "
                         "with python -m repro.runtime.trace)")
    args = ap.parse_args()
    rows = run(args.arch, args.slots, args.max_len, args.requests,
               args.max_new, smoke=args.smoke, seed=args.seed,
               scenario=args.scenario, sys_len=args.sys_len,
               spec_k=args.spec_k, trace_out=args.trace_out)
    print(emit(rows))


if __name__ == "__main__":
    main()
