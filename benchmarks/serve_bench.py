"""Serving engine benchmark: voltra-paged vs seed dense-slot engine.

A mixed-length request trace (every prompt a different length — the
production case the dense engine handles worst) is replayed through both
engines on the same model/params. Reported per engine:

* ``decode_tok_s``  — generated tokens / wall time for the whole trace
  (the number a capacity planner cares about; includes the per-length
  retrace tax the dense engine pays on mixed traffic)
* ``ttft_mean_s``   — mean time-to-first-token across requests
* ``prefill_traces``— distinct prefill compilations: once per LENGTH
  BUCKET for paged (mixed-grained-prefetch analogue), once per distinct
  prompt length for dense
* ``kv_util`` / ``peak_kv_tokens`` — live tokens over allocated page
  capacity at peak, vs the dense engine's static ``slots * max_len``
  reservation (the paper's dynamic-allocation utilization claim)

  PYTHONPATH=src python -m benchmarks.serve_bench [--arch qwen2.5-3b]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.models import api
from repro.runtime.scheduler import Scheduler
from repro.runtime.serving import (DenseServingEngine, PagedServingEngine,
                                   Request)


def _trace(cfg, n_requests: int, max_new: int) -> List[Request]:
    """Mixed-length trace: all prompt lengths distinct (3, 8, 13, ...),
    spanning several power-of-two buckets."""
    return [Request(rid=i,
                    prompt=[(13 * i + j) % cfg.vocab
                            for j in range(3 + 5 * i)],
                    max_new=max_new)
            for i in range(n_requests)]


def _drive(engine, reqs: List[Request], max_steps: int) -> Dict:
    sched = Scheduler(engine)
    for r in reqs:
        sched.add(r)
    t0 = time.perf_counter()
    sched.drain(max_steps=max_steps)
    wall = time.perf_counter() - t0
    done = [r for r in reqs if r.done]
    toks = sum(len(r.generated) for r in done)
    ttfts = [engine.first_token_at[r.rid] - t0 for r in done
             if r.rid in engine.first_token_at]
    row = {
        "engine": type(engine).__name__,
        "requests_done": len(done),
        "tokens": toks,
        "wall_s": wall,
        "decode_tok_s": toks / wall if wall else 0.0,
        "ttft_mean_s": sum(ttfts) / len(ttfts) if ttfts else 0.0,
        "prefill_traces": engine.prefill_traces,
    }
    if isinstance(engine, PagedServingEngine):
        st = engine.pool_stats()
        row["peak_kv_tokens"] = st.peak_pages * st.page_size
        row["kv_util_vs_dense"] = (st.peak_pages * st.page_size
                                   / st.dense_equiv_tokens)
    else:
        row["peak_kv_tokens"] = engine.slots * engine.max_len
        row["kv_util_vs_dense"] = 1.0
    return row


def run(arch: str = "qwen2.5-3b", slots: int = 4, max_len: int = 128,
        n_requests: int = 12, max_new: int = 8) -> List[Dict]:
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    rows = []
    dense = DenseServingEngine(cfg, params, slots=slots, max_len=max_len)
    rows.append(_drive(dense, _trace(cfg, n_requests, max_new), 4000))
    paged = PagedServingEngine(cfg, params, slots=slots, max_len=max_len)
    rows.append(_drive(paged, _trace(cfg, n_requests, max_new), 4000))
    d, p = rows[0], rows[1]
    rows.append({
        "engine": "paged/dense",
        "requests_done": p["requests_done"] - d["requests_done"],
        "tokens": p["tokens"] - d["tokens"],
        "wall_s": d["wall_s"] / p["wall_s"],
        "decode_tok_s": p["decode_tok_s"] / d["decode_tok_s"],
        "ttft_mean_s": d["ttft_mean_s"] / p["ttft_mean_s"]
        if p["ttft_mean_s"] else 0.0,
        "prefill_traces": p["prefill_traces"] - d["prefill_traces"],
        "peak_kv_tokens": p["peak_kv_tokens"] - d["peak_kv_tokens"],
        "kv_util_vs_dense": p["kv_util_vs_dense"],
    })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    rows = run(args.arch, args.slots, args.max_len, args.requests,
               args.max_new)
    print(emit(rows))


if __name__ == "__main__":
    main()
