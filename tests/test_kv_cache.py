"""Paged KV-cache: allocator invariants (host-side, no jax) + paged-engine
behavior (reclamation, admission rejection, dense-engine equivalence)."""
import pytest

from repro.runtime.kv_cache import SCRATCH_PAGE, PageAllocator, PoolStats


# ---------------------------------------------------------------------------
# Allocator (pure host-side)
# ---------------------------------------------------------------------------


def test_pages_track_live_tokens_not_slots_times_max_len():
    """The paper's dynamic-allocation claim: allocated capacity follows
    live tokens, not the dense slots*max_len reservation."""
    slots, max_len, page = 4, 256, 16
    a = PageAllocator(slots * max_len // page, page)
    a.allocate(0, 10)     # 10 tokens -> 1 page
    a.allocate(1, 17)     # 17 tokens -> 2 pages
    assert a.allocated_pages == 3
    assert a.live_tokens == 27
    stats = PoolStats.of(a, slots, max_len)
    assert stats.allocated_pages * page == 48         # 3 pages
    assert stats.dense_equiv_tokens == 1024           # what dense reserves
    assert stats.utilization == pytest.approx(27 / 48)
    # growing by one token inside a page allocates nothing
    assert a.extend_to(0, 11) == 0
    assert a.allocated_pages == 3
    # crossing the boundary allocates exactly one page
    assert a.extend_to(0, 17) not in (0, None)
    assert a.allocated_pages == 4


def test_pages_reclaimed_on_finish():
    a = PageAllocator(8, 16)
    t0 = a.allocate(0, 40)    # 3 pages
    a.allocate(1, 20)         # 2 pages
    assert a.free_pages == 3
    assert a.free_request(0) == 3
    assert a.free_pages == 6
    assert a.live_tokens == 20
    # reclaimed pages are reissued to the next request
    t2 = a.allocate(2, 33)    # 3 pages
    assert set(t2) & set(t0)
    a.check_no_aliasing()


def test_block_tables_never_alias_across_live_requests():
    a = PageAllocator(32, 8)
    for rid in range(6):
        a.allocate(rid, 5 + 7 * rid)
    a.check_no_aliasing()
    # grow everyone a few times; invariant must hold throughout
    for step in range(30):
        for rid in range(6):
            a.extend_to(rid, a.tokens(rid) + 1)
        a.check_no_aliasing()
    # scratch page is never handed out
    for rid in range(6):
        assert SCRATCH_PAGE not in a.block_table(rid)


def test_full_pool_rejects_admission_without_corruption():
    a = PageAllocator(4, 16)
    t0 = a.allocate(0, 33)          # 3 pages
    assert a.allocate(1, 32) is None   # needs 2, only 1 free -> reject
    # rejection left every structure untouched
    assert a.allocated_pages == 3
    assert a.block_table(0) == t0
    assert a.live_requests == 1
    a.check_no_aliasing()
    # and a fitting request still gets in
    assert a.allocate(2, 10) is not None
    a.check_no_aliasing()


def test_extend_exhaustion_leaves_state_unchanged():
    a = PageAllocator(2, 4)
    a.allocate(0, 4)
    a.allocate(1, 4)
    assert a.free_pages == 0
    before = a.block_table(0)
    assert a.extend_to(0, 5) is None    # pool dry: caller must preempt
    assert a.block_table(0) == before
    assert a.tokens(0) == 4
    a.check_no_aliasing()


# ---------------------------------------------------------------------------
# Engine-level (jax; small smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, api.init_params(cfg, jax.random.key(0))


@pytest.mark.slow
def test_paged_engine_matches_dense_engine(qwen):
    """Greedy outputs of the paged engine must be identical to the seed
    dense-slot engine, request for request. attn_impl="gather" pins the
    PR-1 attention path, which is bit-identical to the dense engine's —
    this test isolates the PAGING BOOKKEEPING (tables, scatter, masking).
    The flash-decode kernel path reorders the bf16 accumulation (per-page
    online softmax) and is checked to fp32 tolerance in
    test_paged_attention.py instead of by exact greedy-token match."""
    from repro.runtime.serving import (DenseServingEngine,
                                       PagedServingEngine, Request)
    cfg, params = qwen

    def mk():
        return [Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=5),
                Request(rid=1, prompt=[2, 7], max_new=6),
                Request(rid=2, prompt=[9, 9, 8, 2, 6, 5, 3], max_new=4)]

    dense = DenseServingEngine(cfg, params, slots=2, max_len=32)
    d = {r.rid: r.generated
         for r in dense.run_to_completion(mk(), max_steps=60)}
    paged = PagedServingEngine(cfg, params, slots=2, max_len=32,
                               page_size=8, attn_impl="gather")
    p = {r.rid: r.generated
         for r in paged.run_to_completion(mk(), max_steps=60)}
    assert d == p


@pytest.mark.slow
def test_paged_engine_rejects_admission_when_pool_full(qwen):
    """With a pool too small for two prompts, the second submit must be
    rejected (not corrupt the first), then succeed after the first frees."""
    from repro.runtime.serving import PagedServingEngine, Request
    cfg, params = qwen
    eng = PagedServingEngine(cfg, params, slots=4, max_len=32, page_size=8,
                             num_pages=3)      # 24 usable token slots
    r0 = Request(rid=0, prompt=[1, 2, 3, 4, 5, 6, 7, 8, 9], max_new=3)
    r1 = Request(rid=1, prompt=[8, 9, 1, 2, 3, 4, 5, 6, 7], max_new=3)
    assert eng.submit(r0)
    assert not eng.submit(r1)          # slots free, pages aren't -> reject
    while eng.has_live():
        eng.ensure_decode_capacity()
        eng.step()
    assert r0.done and len(r0.generated) == 3
    assert eng.alloc.allocated_pages == 0       # reclaimed on finish
    assert eng.submit(r1)              # now it fits
    eng.alloc.check_no_aliasing()


@pytest.mark.slow
def test_paged_engine_preempts_and_resumes(qwen):
    """When decode outgrows the pool, the youngest request is preempted and
    later resumed — and still produces its full greedy output."""
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import (DenseServingEngine,
                                       PagedServingEngine, Request)
    cfg, params = qwen

    def mk():
        return [Request(rid=0, prompt=[5, 4, 3, 2, 1, 6, 7], max_new=8),
                Request(rid=1, prompt=[1, 2, 3, 4, 5, 6], max_new=8)]

    dense = DenseServingEngine(cfg, params, slots=2, max_len=32)
    want = {r.rid: r.generated
            for r in dense.run_to_completion(mk(), max_steps=60)}

    # 4 pages of 4 = 16 tokens: both fit at admission, but decode growth
    # (7+8 and 6+8 tokens) must force at least one preemption.
    # attn_impl="gather" for exact-token comparison with dense (see
    # test_paged_engine_matches_dense_engine).
    eng = PagedServingEngine(cfg, params, slots=2, max_len=32, page_size=4,
                             num_pages=4, attn_impl="gather")
    reqs = mk()
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)
    sched.drain(max_steps=400)
    assert sched.preempted >= 1
    assert {r.rid: r.generated for r in reqs} == want
    eng.alloc.check_no_aliasing()
    assert eng.alloc.allocated_pages == 0
