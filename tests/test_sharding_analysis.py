"""Sharding rules (divisibility fallbacks) + HLO cost-analysis parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.analysis import hlo_cost, roofline
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import Rules


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(model=1)   # (n_cpu, 1)


def test_spec_basics(mesh):
    r = Rules(mesh)
    assert r.spec((8, 16), "batch,seq") == P(("pod", "data")[1:][0] if False
                                             else "data")
    # replicated dims drop trailing Nones
    assert r.spec((8,), "") == P()


def test_divisibility_fallback(mesh):
    r = Rules(mesh)
    dp = mesh.shape["data"]
    if dp > 1:
        # a dim not divisible by the mesh axis falls back to replication
        assert r.spec((dp + 1, 4), "batch,") == P()
        assert r.spec((dp * 3, 4), "batch,") == P("data")
    else:
        pytest.skip("single-device mesh")


def test_axis_conflict_fallback(mesh):
    """Two logical dims mapping to the same mesh axis: second replicates."""
    r = Rules(mesh, {"batch": "data", "seq": "data"})
    dp = mesh.shape["data"]
    spec = r.spec((dp * 2, dp * 2), "batch,seq")
    assert spec == P("data")          # seq dropped (conflict)


def test_absent_axis_dropped():
    """'pod' axis is absent on the single-pod mesh: composite rules still
    work (this is what lets the same rules serve both meshes)."""
    m = make_host_mesh(model=1)
    r = Rules(m)
    spec = r.spec((8, 4), "batch,")
    assert spec in (P("data"), P())   # ("pod","data") -> ("data",)


def test_cons_is_identity_math(mesh):
    r = Rules(mesh)
    x = jnp.arange(16.0).reshape(8, 2)
    with compat.set_mesh(mesh):
        y = jax.jit(lambda a: r.cons(a, "batch,"))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------------------
# HLO cost parser (trip-count-aware)
# ---------------------------------------------------------------------------


def test_hlo_cost_counts_matmul_flops():
    M, K, N = 128, 256, 64

    def f(a, b):
        return a @ b

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32))
    cost = hlo_cost.analyze_hlo(lowered.compile().as_text())
    want = 2 * M * K * N
    assert cost.flops == pytest.approx(want, rel=0.05)


def test_hlo_cost_multiplies_loop_trip_counts():
    """A scanned matmul must count L x the per-iteration FLOPs (this is
    the exact bug in XLA's own cost_analysis that hlo_cost fixes)."""
    L, M = 8, 64

    def f(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((L, M, M), jnp.float32))
    cost = hlo_cost.analyze_hlo(lowered.compile().as_text())
    want = L * 2 * M * M * M
    assert cost.flops == pytest.approx(want, rel=0.2)


def test_collective_bytes_parsed():
    mesh = make_host_mesh(model=1)
    if mesh.shape["data"] < 2:
        pytest.skip("need >1 device")
    r = Rules(mesh)
    n = mesh.shape["data"]

    def f(x):
        return x.sum(0)

    with compat.set_mesh(mesh):
        lowered = jax.jit(
            f, in_shardings=r.sharding((n * 4, 8), "batch,"),
            out_shardings=jax.sharding.NamedSharding(mesh, P())).lower(
            jax.ShapeDtypeStruct((n * 4, 8), jnp.float32))
        txt = lowered.compile().as_text()
    cost = hlo_cost.analyze_hlo(txt)
    assert cost.total_coll > 0          # an all-reduce must appear


def test_roofline_terms_positive_and_consistent():
    r = roofline.Roofline(flops=1e12, bytes_hbm=1e11, bytes_coll=5e9,
                          chips=256, coll_breakdown={}, model_flops=2.5e14)
    assert r.t_compute == pytest.approx(1e12 / roofline.PEAK_FLOPS)
    assert r.t_memory == pytest.approx(1e11 / roofline.HBM_BW)
    assert r.t_collective == pytest.approx(5e9 / roofline.ICI_BW)
    assert r.bottleneck == "memory"        # 0.122s > 0.1s > 0.005s
    assert 0 < r.roofline_frac <= 1.0 + 1e-9
    assert r.useful_flops_frac == pytest.approx(2.5e14 / (1e12 * 256))
