"""Per-architecture smoke tests: every assigned arch instantiates a
reduced same-family config and runs forward + one train step + one decode
step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import api
from repro.optim import adamw
from repro.runtime import trainer

# interpret-mode model/kernel tests: minutes on a throttled CPU
pytestmark = pytest.mark.slow

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            ks[2], (B, S, cfg.d_model), jnp.float32).astype(cfg.dtype)
    elif cfg.frontend == "patch":
        ft = max(cfg.frontend_tokens, 4)
        batch["frontend_embeds"] = jax.random.normal(
            ks[2], (B, ft, cfg.d_model), jnp.float32).astype(cfg.dtype)
        batch["tokens"] = batch["tokens"][:, : S - ft]
        batch["labels"] = batch["labels"][:, : S - ft]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits, _, _ = api.forward(cfg, params, batch)
    vocab_padded = logits.shape[-1]
    assert vocab_padded >= cfg.vocab
    assert not bool(jnp.isnan(logits).any())
    loss, aux = api.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    # random init: loss near ln(vocab)
    assert float(aux["ce"]) == pytest.approx(np.log(cfg.vocab), rel=0.35)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_and_finite(arch):
    cfg = get_smoke_config(arch)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0,
                                moment_dtype=cfg.moment_dtype)
    step = jax.jit(trainer.make_train_step(cfg, opt_cfg))
    state = trainer.init_state(cfg, opt_cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0
    # no NaN anywhere in the new state
    for leaf in jax.tree.leaves(new_state["params"]):
        assert not bool(jnp.isnan(leaf.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    batch.pop("labels")
    max_len = S + 8
    logits, cache, pos = api.prefill(cfg, params, batch, max_len=max_len)
    assert logits.shape[0] == B and not bool(jnp.isnan(logits).any())
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = api.decode_step(cfg, params, cache, tok, pos)
    assert logits2.shape[0] == B
    assert not bool(jnp.isnan(logits2).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full (non-smoke) config must carry the exact assigned numbers."""
    spec = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "mamba2-2.7b": (64, 2560, None, None, 0, 50280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }[arch]
    cfg = get_config(arch)
    L, d, h, kv, ff, v = spec
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.d_ff == ff and cfg.vocab == v
    if h is not None:
        assert cfg.num_heads == h and cfg.kv_heads == kv


def test_moe_archs_route_tokens():
    cfg = get_smoke_config("dbrx-132b")
    assert cfg.moe is not None and cfg.moe.num_experts > 0
    params = api.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    loss, aux = api.loss_fn(cfg, params, batch)
    assert float(aux["lb_loss"]) > 0          # router actually engaged


def test_param_counts_plausible():
    """Analytic parameter counts should be in the ballpark of the names."""
    approx = {
        "yi-6b": 6e9, "qwen2.5-3b": 3e9, "granite-3-2b": 2.5e9,
        "mamba2-2.7b": 2.7e9, "recurrentgemma-9b": 9e9,
        "dbrx-132b": 132e9,
    }
    for arch, n in approx.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 1.8 * n, (arch, got)
    # llama4: ~400B total / ~17B active
    cfg = get_config("llama4-maverick-400b-a17b")
    assert 250e9 < cfg.param_count() < 550e9
    assert 10e9 < cfg.active_param_count() < 25e9
