"""Serving: prefill/decode consistency with the full forward pass, and the
slot-based continuous-batching engine."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.runtime.serving import Request, ServingEngine


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "granite-3-2b",
                                  "mamba2-2.7b", "recurrentgemma-9b"])
def test_decode_matches_full_forward(arch):
    """Teacher-forcing equivalence: decoding token t with the cache must
    give the same logits as a full forward over the first t+1 tokens."""
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    T = 12
    toks = jax.random.randint(jax.random.key(1), (1, T), 0, cfg.vocab)

    full_logits, _, _ = api.forward(cfg, params, {"tokens": toks})

    # bf16 params + different accumulation order between the chunked
    # prefill path and the step-by-step recurrence -> loose-ish tolerance
    tol = dict(rtol=3e-2, atol=8e-2)
    prefix = 6
    logits_p, cache, pos = api.prefill(
        cfg, params, {"tokens": toks[:, :prefix]}, max_len=T + 4)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full_logits[:, prefix - 1], np.float32), **tol)

    for t in range(prefix, T):
        logits_d, cache = api.decode_step(cfg, params, cache,
                                          toks[:, t:t + 1], pos)
        pos = pos + 1
        np.testing.assert_allclose(
            np.asarray(logits_d, np.float32),
            np.asarray(full_logits[:, t], np.float32), **tol)


def test_engine_generates_and_frees_slots():
    cfg = get_smoke_config("qwen2.5-3b")
    params = api.init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    reqs = [Request(rid=0, prompt=[1, 2, 3], max_new=4),
            Request(rid=1, prompt=[4, 5], max_new=6),
            Request(rid=2, prompt=[6], max_new=2)]   # 3 reqs, 2 slots
    done = eng.run_to_completion(reqs, max_steps=40)
    assert sorted(r.rid for r in done) == [0, 1, 2]  # continuous batching
    by_id = {r.rid: r for r in done}
    assert len(by_id[0].generated) == 4
    assert len(by_id[1].generated) == 6
    assert len(by_id[2].generated) == 2
    # slots free again afterwards
    assert eng.submit(Request(rid=3, prompt=[6], max_new=2))


def test_engine_deterministic_greedy():
    cfg = get_smoke_config("granite-3-2b")
    params = api.init_params(cfg, jax.random.key(0))
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params, slots=1, max_len=32)
        done = eng.run_to_completion(
            [Request(rid=0, prompt=[7, 8, 9], max_new=5)], max_steps=10)
        outs.append(done[0].generated)
    assert outs[0] == outs[1]


def test_dense_overlong_prompt_rejected_as_done():
    """A prompt at/over the lane length used to break the
    dynamic_update_slice cache merge (prompt > max_len) or silently
    clamp-overwrite the last KV row; the dense engine now mirrors the
    paged engine's reject-as-done guard and keeps serving neighbors."""
    from repro.runtime.serving import DenseServingEngine
    cfg = get_smoke_config("qwen2.5-3b")
    params = api.init_params(cfg, jax.random.key(0))
    eng = DenseServingEngine(cfg, params, slots=2, max_len=16)
    bad = Request(rid=0, prompt=list(range(1, 20)), max_new=4)   # 19 >= 16
    edge = Request(rid=1, prompt=list(range(1, 16)), max_new=4)  # 15 == S-1
    spent = Request(rid=2, prompt=[1, 2], max_new=0)             # no budget
    ok = Request(rid=3, prompt=[1, 2, 3], max_new=3)
    done = eng.run_to_completion([bad, edge, spent, ok], max_steps=40)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3]
    assert bad.generated == [] and edge.generated == []
    assert spent.generated == []
    assert len(ok.generated) == 3          # the healthy neighbor is intact


def test_dense_run_to_completion_raises_on_exhausted_budget():
    """Exhausting max_steps with work in flight must fail loudly (the
    Scheduler.drain contract PR 3 established) instead of returning
    silently truncated outputs."""
    from repro.runtime.scheduler import SchedulerExhausted
    from repro.runtime.serving import DenseServingEngine
    cfg = get_smoke_config("qwen2.5-3b")
    params = api.init_params(cfg, jax.random.key(0))
    eng = DenseServingEngine(cfg, params, slots=1, max_len=32)
    reqs = [Request(rid=0, prompt=[1, 2, 3], max_new=8)]
    with pytest.raises(SchedulerExhausted):
        eng.run_to_completion(reqs, max_steps=2)


def test_engine_batched_isolation():
    """A request's output must not depend on what shares the batch."""
    cfg = get_smoke_config("qwen2.5-3b")
    params = api.init_params(cfg, jax.random.key(0))
    eng1 = ServingEngine(cfg, params, slots=1, max_len=32)
    alone = eng1.run_to_completion(
        [Request(rid=0, prompt=[3, 1, 4], max_new=4)],
        max_steps=10)[0].generated

    eng2 = ServingEngine(cfg, params, slots=2, max_len=32)
    done = eng2.run_to_completion(
        [Request(rid=0, prompt=[3, 1, 4], max_new=4),
         Request(rid=1, prompt=[2, 7, 1, 8, 2], max_new=4)], max_steps=10)
    together = [r for r in done if r.rid == 0][0].generated
    assert alone == together
