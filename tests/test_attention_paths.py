"""Equivalence of the attention execution paths added in §Perf:
chunked (online-softmax scan) vs one-shot (A4) vs oracle, and the
single-pass vs chunked decode cache attention (C3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.models.layers import attend_decode, flash_attention


def _qkv(key, B, Sq, Sk, H, KV, D):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32),
            jax.random.normal(ks[1], (B, Sk, KV, D), jnp.float32),
            jax.random.normal(ks[2], (B, Sk, KV, D), jnp.float32))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 64, 64, 4, 2, 16),
                                   (1, 37, 37, 6, 3, 8),
                                   (2, 128, 128, 4, 4, 32)])
def test_oneshot_matches_chunked(shape, causal):
    B, Sq, Sk, H, KV, D = shape
    q, k, v = _qkv(jax.random.key(0), B, Sq, Sk, H, KV, D)
    a = flash_attention(q, k, v, causal=causal, chunked=True,
                        q_chunk=32, kv_chunk=32)
    b = flash_attention(q, k, v, causal=causal, chunked=False)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(b, ref.mha_ref(q, k, v, causal=causal),
                               rtol=2e-3, atol=2e-3)


def test_oneshot_windowed():
    q, k, v = _qkv(jax.random.key(1), 1, 96, 96, 4, 1, 16)
    a = flash_attention(q, k, v, causal=True, window=32, chunked=True,
                        q_chunk=32, kv_chunk=32)
    b = flash_attention(q, k, v, causal=True, window=32, chunked=False)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_oneshot_kv_valid_and_offset():
    q, k, v = _qkv(jax.random.key(2), 2, 8, 64, 4, 2, 16)
    off = jnp.array([17, 40])
    a = flash_attention(q, k, v, causal=True, q_offset=off, kv_valid=50,
                        chunked=True, q_chunk=8, kv_chunk=16)
    b = flash_attention(q, k, v, causal=True, q_offset=off, kv_valid=50,
                        chunked=False)
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(8, 96), st.integers(1, 4),
       st.booleans())
def test_decode_chunked_matches_single_pass(B, S, KV, windowed):
    """attend_decode with any kv_chunk equals the single-pass result."""
    D, G = 8, 2
    key = jax.random.key(B * 1000 + S)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, 1, KV * G, D), jnp.float32)
    ck = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    cv = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    pos = jax.random.randint(ks[3], (B,), 0, S)
    kw = dict(window=S if windowed else 0)
    single = attend_decode(q, ck, cv, pos, kv_chunk=0, **kw)
    for c in (4, 16, S):
        chunked = attend_decode(q, ck, cv, pos, kv_chunk=c, **kw)
        np.testing.assert_allclose(chunked, single, rtol=2e-3, atol=2e-3)
