"""C1: 3D spatial-utilization model — properties + paper anchors."""
import math

import pytest
from hypothesis import given, strategies as st

from repro.core import spatial, workloads
from repro.core.accel import VOLTRA
from repro.core.workloads import Op

dims = st.integers(min_value=1, max_value=4096)


@given(dims, dims, dims)
def test_util_in_unit_interval(M, K, N):
    op = Op("x", M=M, K=K, N=N)
    for mode in ("strict", "flexible"):
        u = spatial.op_spatial_util_3d(op, mode=mode)
        assert 0.0 < u <= 1.0
    assert 0.0 < spatial.op_spatial_util_2d(op) <= 1.0


@given(dims, dims, dims)
def test_flexible_never_worse_than_strict(M, K, N):
    op = Op("x", M=M, K=K, N=N)
    assert (spatial.op_spatial_util_3d(op, mode="flexible")
            >= spatial.op_spatial_util_3d(op, mode="strict") - 1e-12)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64))
def test_divisible_dims_are_perfect(m8, k8, n8):
    op = Op("x", M=8 * m8, K=8 * k8, N=8 * n8)
    assert spatial.op_spatial_util_3d(op) == pytest.approx(1.0)


@given(dims, dims, dims)
def test_cycles_cover_flops(M, K, N):
    """Ideal cycles x peak MACs >= useful MACs, equality iff util == 1."""
    op = Op("x", M=M, K=K, N=N)
    cyc = spatial.spatial_cycles(op)
    assert cyc * VOLTRA.macs >= op.macs
    u = spatial.op_spatial_util_3d(op)
    assert cyc * VOLTRA.macs * u == pytest.approx(op.macs, rel=1e-9)


def test_gemv_ratio_is_exactly_2x():
    """The paper's headline: a GEMV-dominated workload gains 2.0x over the
    16x32 2D baseline (1/8 vs 1/16 M-edge efficiency)."""
    op = Op("gemv", M=1, K=4096, N=4096)
    u3 = spatial.op_spatial_util_3d(op)
    u2 = spatial.op_spatial_util_2d(op)
    assert u3 == pytest.approx(1 / 8)
    assert u2 == pytest.approx(1 / 16)
    assert u3 / u2 == pytest.approx(2.0)


def test_3d_loses_on_ragged_k():
    """3D is not uniformly better: K=27 (ResNet stem) wastes the K unroll
    that the 2D baseline (temporal K) does not."""
    op = Op("stem", M=12544, K=27, N=64)
    assert spatial.op_spatial_util_3d(op) < spatial.op_spatial_util_2d(op)


def test_paper_band_fig6a():
    """All 8 workloads: 3D util high band; max gain over 2D == 2.0x."""
    gains, utils = [], []
    for wl in workloads.all_workloads().values():
        r = spatial.spatial_report(wl)
        utils.append(r["util_3d"])
        gains.append(r["gain"])
    assert min(utils) > 0.65          # paper floor 69.71%
    assert max(utils) <= 1.0
    assert max(gains) == pytest.approx(2.0, abs=0.01)   # "up to 2.0x"
    geo = math.prod(gains) ** (1 / len(gains))
    assert geo > 1.1                  # 3D wins on aggregate


def test_workload_flops_sane():
    wl = workloads.resnet50()
    assert wl.flops == pytest.approx(7.7e9, rel=0.15)   # ~3.8 GMACs
    wl = workloads.bert_base()
    assert wl.flops == pytest.approx(9.7e10, rel=0.15)
