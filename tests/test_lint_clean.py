"""The tree-is-clean gate: running repro-lint over the real repo (src,
tests, benchmarks) must exit 0 with the shipped (empty) baseline — every
deliberate invariant break in the codebase carries an inline
`# repro-lint: disable=<rule>` marker with its justification, so new
violations are the ONLY thing that can fail this test (and the CI lint
job that runs the same command without jax installed)."""
import os
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def test_repo_lints_clean():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         "src", "tests", "benchmarks"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, (
        "repro-lint found new violations (fix them or add a justified "
        "`# repro-lint: disable=<rule>` marker):\n" + r.stdout + r.stderr)


def test_shipped_baseline_is_empty():
    # the ratchet starts at zero: nothing is grandfathered
    base = REPO_ROOT / ".repro-lint-baseline"
    assert base.exists(), "shipped baseline file missing"
    lines = [ln for ln in base.read_text().splitlines()
             if ln.strip() and not ln.lstrip().startswith("#")]
    assert lines == [], f"baseline must ship empty, has: {lines[:5]}"
