"""Hypothesis property tests for the kernel layer: random shapes/blocks
always match the oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.gemm_os import gemm_os

dim = st.integers(min_value=1, max_value=96)
blk = st.sampled_from([8, 16, 32, 64])


@settings(max_examples=15, deadline=None)
@given(dim, dim, dim, blk, blk, blk)
def test_gemm_any_shape_any_block(M, K, N, bm, bn, bk):
    x = jax.random.normal(jax.random.key(M * 7 + K), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.key(N * 13 + K), (K, N), jnp.float32)
    got = gemm_os(x, w, block=(bm, bn, bk), interpret=True)
    np.testing.assert_allclose(got, ref.gemm_ref(x, w),
                               rtol=3e-3, atol=3e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 64), st.integers(1, 128), st.integers(1, 64),
       st.floats(1e-4, 1.0))
def test_quant_gemm_matches_exactly(M, K, N, scale):
    x = jax.random.randint(jax.random.key(M + K), (M, K), -128, 128
                           ).astype(jnp.int8)
    w = jax.random.randint(jax.random.key(N + K), (K, N), -128, 128
                           ).astype(jnp.int8)
    got = ops.quant_matmul(x, w, float(scale), block=(32, 32, 32))
    np.testing.assert_array_equal(
        got, ref.gemm_ref(x, w, quant_scale=float(scale)))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(4, 48), st.integers(1, 4),
       st.integers(1, 2), st.sampled_from([8, 16, 32]))
def test_mha_any_shape(B, S, KV, G, D):
    H = KV * G
    q = jax.random.normal(jax.random.key(B * S), (B, S, H, D), jnp.float32)
    k = jax.random.normal(jax.random.key(B + S), (B, S, KV, D), jnp.float32)
    v = jax.random.normal(jax.random.key(B - S), (B, S, KV, D), jnp.float32)
    got = ops.attention(q, k, v, bq=16, bk=16)
    np.testing.assert_allclose(got, ref.mha_ref(q, k, v),
                               rtol=3e-3, atol=3e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 12), st.integers(2, 12), st.sampled_from([8, 16]),
       st.sampled_from([1, 3]), st.sampled_from([1, 2]))
def test_conv_any_shape(H, W, C, R, stride):
    x = jax.random.normal(jax.random.key(H * W), (1, H, W, C), jnp.float32)
    w = jax.random.normal(jax.random.key(C), (R, R, C, 8), jnp.float32)
    got = ops.conv2d(x, w, stride=stride)
    np.testing.assert_allclose(got, ref.conv2d_ref(x, w, stride=stride),
                               rtol=3e-3, atol=3e-3)
