"""Speculative multi-token decode: drafter units, rollback allocator
(truncate_to) invariants incl. a hypothesis interleaving property test,
scheduler admission-budget accounting, and engine-level exactness —
speculative greedy must reproduce the single-token engine's outputs
token-for-token under both attn impls, through preemption-resume, the
prefix cache, and the max_len context cap."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.runtime.drafter import ngram_propose
from repro.runtime.kv_cache import PageAllocator

# ---------------------------------------------------------------------------
# Drafter (pure host-side)
# ---------------------------------------------------------------------------


def test_ngram_propose_longest_suffix_most_recent():
    # suffix [1, 2] occurs twice; the MOST RECENT occurrence (index 4)
    # wins, so the continuation is [9, 9], not [7, 8]
    assert ngram_propose([1, 2, 7, 8, 1, 2, 9, 9, 1, 2], 2) == [9, 9]
    # longest n-gram first: [2, 3] matches even though [3] alone also does
    assert ngram_propose([2, 3, 5, 4, 2, 3], 1) == [5]


def test_ngram_propose_k_caps_and_truncates():
    ctx = [1, 2, 3, 4, 5, 1, 2]
    assert ngram_propose(ctx, 2) == [3, 4]
    assert ngram_propose(ctx, 10) == [3, 4, 5, 1, 2]   # runs out of context


def test_ngram_propose_no_match_is_empty():
    assert ngram_propose([1, 2, 3, 4, 5], 4) == []     # nothing repeats
    assert ngram_propose([7], 4) == []                 # too short
    assert ngram_propose([1, 2, 1, 2], 0) == []        # k = 0


def test_ngram_propose_unigram_fallback():
    # no 2-gram repeats, but token 5 does: unigram match proposes its
    # continuation
    assert ngram_propose([5, 1, 9, 5], 2) == [1, 9]


# ---------------------------------------------------------------------------
# Rollback allocator (pure host-side)
# ---------------------------------------------------------------------------


def test_truncate_drops_whole_pages_past_accept_point():
    a = PageAllocator(8, 4)
    t = a.allocate(0, 14)                  # 4 pages provisioned
    assert a.truncate_to(0, 9) == 1        # 9 tokens -> 3 pages
    assert a.block_table(0) == t[:3]
    assert a.tokens(0) == 9
    assert a.free_pages == 5
    a.check_no_aliasing()
    # the dropped page is immediately reissuable
    assert a.extend_to(0, 13) == t[3]      # LIFO: hottest page comes back
    a.check_no_aliasing()


def test_truncate_within_page_drops_nothing():
    a = PageAllocator(4, 8)
    a.allocate(0, 10)                      # 2 pages
    assert a.truncate_to(0, 9) == 0        # still 2 pages
    assert a.tokens(0) == 9
    a.check_no_aliasing()


def test_truncate_is_refcount_safe_for_shared_and_pinned_pages():
    a = PageAllocator(8, 4)
    t0 = a.allocate(0, 12)                 # 3 pages
    a.cache_pin(t0[2])                     # radix tree holds the tail page
    t1 = a.allocate_shared(1, 12, t0)      # full-table sharing
    assert a.truncate_to(1, 5) == 1        # rid 1 drops blocks 2 (shared)
    assert a.truncate_to(1, 4) == 1        # ... and block 1
    # shared pages survive rid 0's references; nothing came free
    assert a.ref(t0[1]) == 1 and a.ref(t0[2]) == 2    # table + pin
    assert a.free_pages == 5
    a.check()
    a.free_request(0)
    assert a.ref(t0[2]) == 1               # pin alone keeps it alive
    assert t1[0] == t0[0] and a.ref(t0[0]) == 1       # rid 1 still holds it
    a.check()


def test_truncate_rejects_growth_and_zero():
    a = PageAllocator(4, 4)
    a.allocate(0, 6)
    with pytest.raises(AssertionError):
        a.truncate_to(0, 7)                # truncate cannot grow
    with pytest.raises(AssertionError):
        a.truncate_to(0, 0)                # a live request keeps >= 1 token


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_truncate_interleavings_keep_invariants(data):
    """Property: random allocate / extend / truncate / free interleavings
    preserve every pool invariant (check()) and the unique-owner page
    accounting — allocated pages always equal exactly what the live
    requests' token counts need (tests/test_pdma_property.py style,
    applied to the speculative rollback path)."""
    page = data.draw(st.sampled_from([4, 8]))
    a = PageAllocator(data.draw(st.integers(min_value=8, max_value=24)),
                      page)
    live = {}
    next_rid = 0
    for _ in range(data.draw(st.integers(min_value=1, max_value=40))):
        op = data.draw(st.sampled_from(
            ["alloc", "extend", "truncate", "free"]))
        if op == "alloc" or not live:
            n = data.draw(st.integers(min_value=1, max_value=3 * page))
            if a.allocate(next_rid, n) is not None:
                live[next_rid] = n
            next_rid += 1
        elif op == "extend":
            rid = data.draw(st.sampled_from(sorted(live)))
            # one decode step's worth: at most a page boundary crossing
            n = live[rid] + data.draw(st.integers(min_value=1,
                                                  max_value=page))
            if a.extend_to(rid, n) is not None:
                live[rid] = n
        elif op == "truncate":
            rid = data.draw(st.sampled_from(sorted(live)))
            n = data.draw(st.integers(min_value=1, max_value=live[rid]))
            a.truncate_to(rid, n)
            live[rid] = n
        else:
            rid = data.draw(st.sampled_from(sorted(live)))
            a.free_request(rid)
            del live[rid]
        a.check_no_aliasing()
        assert a.allocated_pages == sum(a.pages_for(n)
                                        for n in live.values())
        for rid, n in live.items():
            assert a.tokens(rid) == n
            assert len(a.block_table(rid)) == a.pages_for(n)
    for rid in sorted(live):
        a.free_request(rid)
    assert a.allocated_pages == 0 and a.free_pages == a.num_pages


# ---------------------------------------------------------------------------
# Scheduler admission budget (host-side; stub engine)
# ---------------------------------------------------------------------------


class _StubEngine:
    """One-slot engine: prompts of length >= 8 are degenerate (dropped
    as done WITHOUT prefilling, like both real engines' guards); real
    submits append a token (the prefill's sample)."""

    def __init__(self):
        self.live = [None]
        self.prefills = 0

    def submit(self, req):
        if len(req.prompt) >= 8:
            req.done = True
            return True
        if self.live[0] is not None:
            return False
        self.prefills += 1
        req.generated.append(0)
        self.live[0] = req
        return True

    def step(self):
        r = self.live[0]
        if r is not None:
            r.generated.append(0)
            if len(r.generated) >= r.max_new:
                r.done = True
                self.live[0] = None
        return []

    def has_live(self):
        return self.live[0] is not None


def test_admit_budget_not_charged_for_degenerate_drops():
    """A stream of unservable requests dropped-as-done must not consume
    the per-tick admission budget and starve the real request behind
    them."""
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import Request
    eng = _StubEngine()
    sched = Scheduler(eng, max_admits_per_step=1)
    for i in range(3):                      # three degenerates first
        sched.add(Request(rid=i, prompt=list(range(9)), max_new=4))
    real = Request(rid=9, prompt=[1, 2], max_new=2)
    sched.add(real)
    sched.tick()
    # every degenerate was drained AND the real request was prefilled in
    # the same tick — the budget was only charged for the actual prefill
    assert eng.prefills == 1
    assert not sched.pending
    sched.drain(max_steps=10)
    assert real.done and len(real.generated) == 2


def test_admit_budget_still_caps_real_prefills():
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import Request
    eng = _StubEngine()
    sched = Scheduler(eng, max_admits_per_step=1)
    r0 = Request(rid=0, prompt=[1], max_new=9)
    r1 = Request(rid=1, prompt=[2], max_new=9)
    sched.add(r0)
    sched.add(r1)
    sched.tick()
    assert eng.prefills == 1               # budget caps at one real prefill
    assert len(sched.pending) == 1


# ---------------------------------------------------------------------------
# Engine-level exactness (jax; small smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, api.init_params(cfg, jax.random.key(0))


def _mk_reqs(max_new=10):
    from repro.runtime.serving import Request
    # repetitive prompts (so the n-gram drafter hits) + a non-repeating
    # one (so the all-miss fallback path runs too)
    return [Request(rid=0, prompt=[3, 1, 4, 1, 5, 3, 1, 4, 1],
                    max_new=max_new),
            Request(rid=1, prompt=[2, 7, 2, 7, 2, 7], max_new=max_new),
            Request(rid=2, prompt=[9, 8, 7], max_new=max_new // 2)]


def _run(cfg, params, reqs, *, max_steps=400, **kw):
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import PagedServingEngine
    eng = PagedServingEngine(cfg, params, slots=kw.pop("slots", 2),
                             max_len=kw.pop("max_len", 64),
                             page_size=kw.pop("page_size", 8), **kw)
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)
    sched.drain(max_steps=max_steps)
    eng.check()
    return eng, sched


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["gather", "kernel"])
def test_speculative_greedy_equals_plain_greedy(qwen, impl):
    """The acceptance rule's whole contract: every emitted token is an
    argmax row, so spec_k > 0 changes WHEN tokens are computed, never
    WHICH — outputs equal the T=1 engine's exactly, under both attn
    impls."""
    cfg, params = qwen
    want_reqs = _mk_reqs()
    _run(cfg, params, want_reqs, attn_impl=impl)
    want = {r.rid: r.generated for r in want_reqs}

    got_reqs = _mk_reqs()
    eng, _ = _run(cfg, params, got_reqs, attn_impl=impl, spec_k=4)
    assert {r.rid: r.generated for r in got_reqs} == want
    ss = eng.spec_stats()
    assert ss["spec_drafted"] > 0          # the drafter did engage
    assert eng.alloc.allocated_pages == 0  # rollback + finish reclaimed all


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["gather", "kernel"])
def test_speculative_with_preemption_resumes_exactly(qwen, impl):
    """A pool too small for both requests' K+1 token headroom forces
    preemption mid-speculation; resumed requests must still match the
    plain engine token-for-token and leak no pages."""
    cfg, params = qwen
    want_reqs = _mk_reqs(max_new=8)[:2]
    _run(cfg, params, want_reqs, attn_impl=impl, max_len=32,
         page_size=4, num_pages=6)
    want = {r.rid: r.generated for r in want_reqs}

    got_reqs = _mk_reqs(max_new=8)[:2]
    eng, sched = _run(cfg, params, got_reqs, attn_impl=impl, max_len=32,
                      page_size=4, num_pages=6, spec_k=3)
    assert {r.rid: r.generated for r in got_reqs} == want
    assert sched.preempted >= 1
    assert eng.alloc.allocated_pages == 0
    eng.alloc.check_no_aliasing()


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["gather", "kernel"])
def test_speculative_with_prefix_cache_exact(qwen, impl):
    """Speculation composes with prefix sharing: CoW write exclusivity is
    enforced over the whole K+1 write range and rollback decrefs never
    free a page the radix tree still pins."""
    from repro.runtime.serving import Request
    cfg, params = qwen
    sys = [7, 3, 9, 1, 4, 4, 2, 8, 6, 5]

    def mk():
        return [Request(rid=0, prompt=sys + [11, 12], max_new=6),
                Request(rid=1, prompt=sys + [13, 14, 15], max_new=6),
                Request(rid=2, prompt=sys + [11, 12], max_new=6)]

    want_reqs = mk()
    _run(cfg, params, want_reqs, attn_impl=impl, max_len=32,
         page_size=4)
    want = {r.rid: r.generated for r in want_reqs}

    got_reqs = mk()
    eng, _ = _run(cfg, params, got_reqs, attn_impl=impl, max_len=32,
                  page_size=4, prefix_cache=True, spec_k=3)
    assert {r.rid: r.generated for r in got_reqs} == want
    assert eng.prefix.hits >= 2
    eng.check()


@pytest.mark.slow
def test_speculative_respects_max_len_cap(qwen):
    """Unbounded max_new: both engines must truncate at the max_len - 1
    context cap at the same token — the verify block's overflow rows
    (positions past max_len) write to scratch and their logits are
    discarded, never emitted."""
    from repro.runtime.serving import Request
    cfg, params = qwen

    def mk():
        return [Request(rid=0, prompt=[5, 4, 3, 2, 1], max_new=1000),
                Request(rid=1, prompt=[1, 2, 1, 2, 1, 2], max_new=1000)]

    want_reqs = mk()
    _run(cfg, params, want_reqs, attn_impl="gather", max_len=16,
         page_size=4)
    want = {r.rid: r.generated for r in want_reqs}

    got_reqs = mk()
    eng, _ = _run(cfg, params, got_reqs, attn_impl="gather", max_len=16,
                  page_size=4, spec_k=3)
    assert {r.rid: r.generated for r in got_reqs} == want
    assert all(len(g) > 0 for g in want.values())
    assert eng.alloc.allocated_pages == 0


def test_speculative_runs_sampled(qwen):
    """ISSUE 9 lifted the spec_k => temperature == 0 restriction: the
    verify step rejection-samples drafts against the decode policy, so a
    sampled engine with spec_k constructs AND serves (the distribution
    match itself is tests/test_sampling.py's chi-square suite)."""
    from repro.runtime.serving import PagedServingEngine, Request
    cfg, params = qwen
    eng = PagedServingEngine(cfg, params, spec_k=4, temperature=0.7,
                             attn_impl="gather", max_len=32, page_size=4)
    reqs = [Request(rid=0, prompt=[1, 2, 1, 2, 1, 2], max_new=8),
            Request(rid=1, prompt=[5, 4, 3, 2, 1], max_new=8)]
    done = eng.run_to_completion(reqs)
    assert len(done) == 2
    assert all(len(r.generated) > 0 for r in done)
    assert eng.alloc.allocated_pages == 0
    assert eng.metrics()["sampling.sampled_requests"] == 2.0


def test_drafter_requires_spec_k(qwen):
    from repro.runtime.drafter import NgramDrafter
    from repro.runtime.serving import PagedServingEngine
    cfg, params = qwen
    with pytest.raises(ValueError, match="spec_k"):
        PagedServingEngine(cfg, params, drafter=NgramDrafter())
