"""Training integration: loss decreases, grad accumulation is consistent,
checkpoint/restore + preemption resume work, compression round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_smoke_config
from repro.data.synthetic import DataConfig, SyntheticDataset
from repro.optim import adamw
from repro.parallel import compression
from repro.runtime.trainer import StragglerMonitor, Trainer, init_state, \
    make_train_step


def _mk_trainer(tmp, **kw):
    cfg = get_smoke_config("qwen2.5-3b")
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=10,
                            moment_dtype=cfg.moment_dtype)
    return Trainer(cfg, opt, SyntheticDataset(dc),
                   ckpt_dir=str(tmp) if tmp else None,
                   log_fn=lambda s: None, **kw)


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    tr = _mk_trainer(None, save_every=0, log_every=1)
    tr.run(40)
    first = np.mean([h["loss"] for h in tr.history[:5]])
    last = np.mean([h["loss"] for h in tr.history[-5:]])
    assert last < first - 0.3, (first, last)


@pytest.mark.slow
def test_grad_accum_matches_full_batch():
    cfg = get_smoke_config("granite-3-2b")
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
    ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=16,
                                     global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in next(iter(ds)).items()}
    s0 = init_state(cfg, opt, jax.random.key(0))
    full = jax.jit(make_train_step(cfg, opt))
    accum = jax.jit(make_train_step(cfg, opt, grad_accum=4))
    sf, mf = full(s0, batch)
    sa, ma = accum(s0, batch)
    np.testing.assert_allclose(float(mf["loss"]), float(ma["loss"]),
                               rtol=2e-2)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        sf["params"], sa["params"])
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("yi-6b")
    opt = adamw.AdamWConfig()
    state = init_state(cfg, opt, jax.random.key(0))
    path = os.path.join(tmp_path, "step_00000001")
    ckpt.save(path, state, extra={"step": 1, "data": {"step": 1}})
    like = jax.tree.map(np.asarray, state)
    restored, extra = ckpt.restore(path, like)
    assert extra["step"] == 1
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        state, restored)


@pytest.mark.slow
def test_preemption_resume(tmp_path):
    """Kill after 10 steps, restart, confirm step counter + data cursor
    resume and training continues to the same state as an uninterrupted
    run (bitwise on params)."""
    t1 = _mk_trainer(tmp_path, save_every=10, log_every=5)
    t1.run(10)
    t1.checkpointer.wait()
    del t1
    t2 = _mk_trainer(tmp_path, save_every=10, log_every=5)
    assert t2.step == 10                      # resumed
    assert t2.dataset.step == 10              # data cursor restored
    t2.run(5)

    t3 = _mk_trainer(None, save_every=0, log_every=5)
    t3.run(15)
    a = jax.tree.leaves(t2.state["params"])[0]
    b = jax.tree.leaves(t3.state["params"])[0]
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-5)


def test_async_checkpointer_atomic(tmp_path):
    state = {"x": jnp.arange(10)}
    c = ckpt.AsyncCheckpointer()
    p = os.path.join(tmp_path, "step_00000005")
    c.save(p, state, extra={"step": 5})
    c.wait()
    assert ckpt.latest_step_dir(str(tmp_path)).endswith("step_00000005")
    # no partial tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if "tmp" in d]


def test_straggler_monitor_flags_slow_steps():
    m = StragglerMonitor(alpha=0.5, threshold=2.0)
    assert not m.observe(0.1)
    assert not m.observe(0.1)
    assert m.observe(1.0)          # 10x slower
    assert m.slow_steps == 1


def test_compression_error_feedback_converges():
    """int8 compression with error feedback: the quantization error is
    carried, so the accumulated compressed signal tracks the true sum."""
    key = jax.random.key(0)
    g = jax.random.normal(key, (256,)) * 1e-3
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, err = compression.compress(g, err)
        total = total + q.astype(jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(total), np.asarray(g) * 50,
                               rtol=0.05, atol=1e-4)
