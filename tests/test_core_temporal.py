"""C2: MGDP temporal model — event sim vs closed form + paper anchors."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import temporal, workloads
from repro.core.workloads import Op


@pytest.mark.parametrize("k_beats", [2, 4, 8, 32, 128, 384])
@pytest.mark.parametrize("strided", [False, True])
def test_mgdp_beats_plain_in_sim(k_beats, strided):
    s_m = temporal.simulate_tile(k_beats, mgdp=True, strided_input=strided)
    s_p = temporal.simulate_tile(k_beats, mgdp=False, strided_input=strided)
    assert s_m.util >= s_p.util - 0.02
    assert s_m.compute_cycles == s_p.compute_cycles  # same work done


@pytest.mark.parametrize("k_beats", [8, 32, 128, 384])
def test_closed_form_tracks_sim_mgdp(k_beats):
    sim = temporal.simulate_tile(k_beats, mgdp=True, n_tiles=16)
    op = Op("x", M=8, K=k_beats * 8, N=8)
    closed = temporal.op_temporal_util(op, mgdp=True)
    assert abs(sim.util - closed) < 0.15


@pytest.mark.parametrize("k_beats", [8, 32, 128, 384])
def test_closed_form_tracks_sim_plain(k_beats):
    sim = temporal.simulate_tile(k_beats, mgdp=False, n_tiles=16,
                                 strided_input=False)
    op = Op("x", M=8, K=k_beats * 8, N=8)
    closed = temporal.op_temporal_util(op, mgdp=False, strided_input=False)
    # plain is a structural model; agree in regime, not in decimals
    assert abs(sim.util - closed) < 0.2
    assert closed < 0.6 and sim.util < 0.6


@given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096))
def test_util_bounds_and_order(M, K, N):
    op = Op("x", M=M, K=K, N=N)
    um = temporal.op_temporal_util(op, mgdp=True)
    up = temporal.op_temporal_util(op, mgdp=False)
    assert 0.0 < up < um <= 1.0


@given(st.integers(2, 512))
def test_util_monotone_in_k(k):
    """Longer K sweeps amortize the retire path: util non-decreasing."""
    u1 = temporal.op_temporal_util(Op("a", M=8, K=8 * k, N=8))
    u2 = temporal.op_temporal_util(Op("b", M=8, K=8 * (k + 1), N=8))
    assert u2 >= u1 - 1e-9


def test_paper_band_fig6b():
    """MGDP utilization band and gains vs the paper's 76.99-97.32% /
    2.12-2.94x."""
    utils, gains = [], []
    for wl in workloads.all_workloads().values():
        r = temporal.temporal_report(wl)
        utils.append(r["util_mgdp"])
        gains.append(r["gain"])
    assert 0.74 <= min(utils) <= 0.82      # paper floor 0.7699
    assert 0.95 <= max(utils) <= 0.99      # paper ceiling 0.9732
    assert all(2.0 <= g <= 3.0 for g in gains)   # paper 2.12-2.94


def test_simd_drain_binds_only_short_k():
    """C4 anchor: the 8-lane quant SIMD costs ~nothing on ResNet50-like
    K (>=576) but caps depthwise-like K=9 tiles — the 0.7% claim."""
    long_k = temporal.op_temporal_util(Op("r", M=3136, K=576, N=64))
    short_k = temporal.op_temporal_util(Op("d", M=3136, K=9, N=1))
    assert long_k > 0.95
    assert short_k <= 0.25 + 1e-6
    # ResNet50 aggregate loses <2% to the drain limit
    wl = workloads.resnet50()
    r = temporal.workload_temporal_util(wl, mgdp=True)
    no_drain = temporal.workload_temporal_util(
        workloads.Workload("nodrain", tuple(
            Op(o.name, M=o.M, K=max(o.K, 64), N=o.N, repeat=o.repeat,
               kind=o.kind) for o in wl.ops)), mgdp=True)
    assert (no_drain - r) / no_drain < 0.05


@settings(max_examples=10)
@given(st.integers(2, 64), st.booleans())
def test_sim_conserves_work(k_beats, mgdp):
    n_tiles = 8
    s = temporal.simulate_tile(k_beats, mgdp=mgdp, n_tiles=n_tiles)
    assert s.compute_cycles == k_beats * n_tiles
    assert s.total_cycles >= s.compute_cycles
