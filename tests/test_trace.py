"""Tracer unit tests: Chrome Trace Event schema, ring-buffer bounds, and
the disabled-tracer no-op contract (ISSUE 8). Pure host-side — no jax."""
import json

from repro.runtime.trace import (NOOP_SPAN, NULL_TRACER, Tracer,
                                 default_tracer, percentile,
                                 set_default_tracer, validate_trace)


# -- schema / export ------------------------------------------------------

def test_export_validates_and_round_trips(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("decode_tick"):
        with tr.span("device_dispatch"):
            pass
        with tr.span("host_sync"):
            pass
    tr.instant("first_token", args={"rid": 0})
    tr.counter("pool_pages", {"allocated": 3.0, "free": 5.0})
    tr.begin_async("request", 0)
    tr.end_async("request", 0)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    obj = json.loads(path.read_text())
    assert validate_trace(obj) == []
    evs = obj["traceEvents"]
    # metadata rows label the process and every tid used
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    tnames = {e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"engine", "requests"} <= tnames
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert {"decode_tick", "device_dispatch", "host_sync"} <= names
    assert obj["otherData"]["dropped_events"] == 0


def test_spans_record_at_exit_with_nonneg_duration():
    tr = Tracer(enabled=True)
    with tr.span("outer"):
        assert tr.events() == []          # complete events land on EXIT
        with tr.span("inner"):
            pass
    inner, outer = tr.events()
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert inner["ph"] == outer["ph"] == "X"
    for ev in (inner, outer):
        assert isinstance(ev["ts"], int) and ev["ts"] >= 0
        assert isinstance(ev["dur"], int) and ev["dur"] >= 0
    # the child is contained in the parent on the same tid
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]


def test_phase_walls_aggregates_by_name():
    tr = Tracer(enabled=True)
    for _ in range(3):
        with tr.span("tick"):
            pass
    walls = tr.phase_walls()
    assert walls["tick"][0] == 3
    assert walls["tick"][1] >= 0.0
    assert "tick" in tr.format_phase_walls()


# -- ring buffer ----------------------------------------------------------

def test_ring_buffer_drops_oldest_without_corrupting_output(tmp_path):
    tr = Tracer(enabled=True, capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.events()) == 4
    assert tr.dropped_events == 6
    assert [e["name"] for e in tr.events()] == ["s6", "s7", "s8", "s9"]
    path = tmp_path / "trace.json"
    tr.export(str(path))
    obj = json.loads(path.read_text())
    assert validate_trace(obj) == []      # truncated trace is still valid
    assert obj["otherData"]["dropped_events"] == 6


def test_dropped_async_begin_does_not_fail_validation():
    tr = Tracer(enabled=True, capacity=2)
    tr.begin_async("request", 0)
    with tr.span("a"):                    # evicts the 'b' row
        pass
    with tr.span("b"):
        pass
    tr.end_async("request", 0)            # orphaned 'e', but declared
    assert tr.dropped_events > 0
    assert validate_trace(tr.to_dict()) == []


# -- disabled tracer is a true no-op --------------------------------------

def test_disabled_tracer_allocates_nothing():
    tr = Tracer(enabled=False)
    assert not tr                          # guards arg-dict construction
    # the SAME shared context manager object every call: no per-call span
    assert tr.span("x") is NOOP_SPAN
    assert tr.span("y", tid="tier") is NOOP_SPAN
    with tr.span("x"):
        pass
    tr.instant("i")
    tr.counter("c", {"v": 1.0})
    tr.begin_async("request", 1)
    tr.end_async("request", 1)
    assert tr.events() == []
    assert tr.events_recorded == 0
    assert NULL_TRACER.span("z") is NOOP_SPAN


def test_default_tracer_install_and_restore():
    assert default_tracer() is NULL_TRACER
    tr = Tracer(enabled=True)
    set_default_tracer(tr)
    try:
        assert default_tracer() is tr
    finally:
        set_default_tracer(None)
    assert default_tracer() is NULL_TRACER


# -- validator catches malformed traces -----------------------------------

def test_validator_rejects_bad_top_level():
    assert validate_trace([]) != []
    assert validate_trace({"events": []}) != []
    assert validate_trace({"traceEvents": "nope"}) != []


def test_validator_rejects_bad_events():
    base = {"pid": 0, "tid": 0, "ts": 0}
    bad = [
        dict(base, ph="Z", name="x"),                      # unknown phase
        dict(base, ph="X", name="x"),                      # X without dur
        dict(base, ph="X", name="x", dur=-1),              # negative dur
        dict(base, ph="X", dur=1),                         # X without name
        {"ph": "X", "name": "x", "ts": 0, "dur": 1, "tid": 0},  # no pid
        dict(base, ph="C", name="c"),                      # C without args
        dict(base, ph="e", name="r"),                      # e without id/cat
    ]
    for ev in bad:
        assert validate_trace({"traceEvents": [ev]}) != [], ev


def test_validator_rejects_partial_overlap_on_one_track():
    evs = [{"ph": "X", "name": "a", "ts": 0, "dur": 10, "pid": 0, "tid": 0},
           {"ph": "X", "name": "b", "ts": 5, "dur": 10, "pid": 0, "tid": 0}]
    errs = validate_trace({"traceEvents": evs})
    assert any("must nest" in e for e in errs)
    # same intervals on DIFFERENT tracks are fine
    evs[1]["tid"] = 1
    assert validate_trace({"traceEvents": evs}) == []


def test_validator_rejects_unmatched_async_end_when_nothing_dropped():
    evs = [{"ph": "e", "name": "request", "cat": "request", "id": "7",
            "ts": 0, "pid": 0, "tid": 0}]
    errs = validate_trace({"traceEvents": evs})
    assert any("async end without matching begin" in e for e in errs)


# -- percentile helper ----------------------------------------------------

def test_percentile():
    assert percentile([], 0.5) == 0.0
    assert percentile([3.0], 0.95) == 3.0
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0.0) == 1.0
    assert percentile(xs, 1.0) == 4.0
    assert percentile(xs, 0.5) == 2.5
    assert percentile(list(reversed(xs)), 0.5) == 2.5   # sorts internally
