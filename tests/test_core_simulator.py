"""End-to-end simulator — Table I / Fig. 6(c) / Fig. 7 anchors."""
import math

import pytest

from repro.core import simulator as sim
from repro.core import workloads


def test_table1_headline_numbers():
    t = sim.table1()
    assert t["peak_tops"] == pytest.approx(0.8192)          # 512 MACs @800MHz
    assert t["area_eff_tops_mm2"] == pytest.approx(1.25, abs=0.01)
    assert t["peak_tops_per_w"] == pytest.approx(1.60, rel=0.05)
    # measured band 171-981 mW; the calibrated model sits within ~12%
    assert t["power_mw_min"] == pytest.approx(171, rel=0.15)
    assert t["power_mw_max"] == pytest.approx(981, rel=0.15)


def test_fig6c_latency_band():
    gains = []
    for wl in workloads.all_workloads().values():
        r = sim.latency_report(wl)
        gains.append(r["gain_serial"])
        # sanity: both sides do the same MACs
        assert r["voltra_compute_cycles"] > 0
    # paper band 1.15-2.36x; shared+PDMA never loses
    assert min(gains) >= 0.99
    assert 1.8 <= max(gains) <= 2.6
    geo = math.prod(gains) ** (1 / len(gains))
    assert geo > 1.25


def test_separated_has_higher_temporal_util_but_loses_on_dma():
    """The paper's own observation: separated buffers avoid contention
    (slightly fewer compute cycles) yet lose overall to DMA traffic."""
    wl = workloads.bert_base()
    v = sim.simulate_workload(wl, "voltra")
    s = sim.simulate_workload(wl, "separated")
    assert s.cycles_compute <= v.cycles_compute          # fewer stalls
    assert s.cycles_dma > 1.5 * v.cycles_dma             # much more DMA
    assert s.latency_serial > v.latency_serial


def test_plain_shared_much_slower_than_voltra():
    wl = workloads.vit_b()
    v = sim.simulate_workload(wl, "voltra")
    p = sim.simulate_workload(wl, "plain_shared")
    assert p.cycles_compute > 2.0 * v.cycles_compute     # Fig 6(b) regime


def test_fig7b_efficiency_falls_with_voltage():
    effs = [sim.gemm_efficiency(96, 96, 96, vdd=v)["tops_per_w"]
            for v in (0.6, 0.7, 0.8, 0.9, 1.0)]
    assert all(a > b for a, b in zip(effs, effs[1:]))
    tops = [sim.gemm_efficiency(96, 96, 96, vdd=v)["tops"]
            for v in (0.6, 0.8, 1.0)]
    assert all(a < b for a, b in zip(tops, tops[1:]))    # throughput rises


def test_fig7d_efficiency_rises_with_size_onchip():
    """Bigger on-chip GEMMs amortize retire/edge effects (the paper's
    size trend, within the preloaded regime it measures)."""
    effs = [sim.gemm_efficiency(n, n, n)["tops_per_w"]
            for n in (32, 64, 96, 128)]
    assert all(a <= b + 1e-9 for a, b in zip(effs, effs[1:]))


def test_fig7d_k_dim_strongest():
    """K growth (output-stationary reuse) helps more than M/N growth."""
    base = sim.gemm_efficiency(96, 96, 96)["tops_per_w"]
    k4 = sim.gemm_efficiency(96, 384, 96)["tops_per_w"]
    m4 = sim.gemm_efficiency(384, 96, 96)["tops_per_w"]
    assert k4 >= base
    assert k4 >= m4


def test_fig7c_sparsity_raises_efficiency():
    e0 = sim.sparsity_efficiency(96, 96, 96, weight_sparsity=0.0)
    e5 = sim.sparsity_efficiency(96, 96, 96, weight_sparsity=0.5)
    e9 = sim.sparsity_efficiency(96, 96, 96, weight_sparsity=0.9)
    assert e0 < e5 < e9
    lo_toggle = sim.sparsity_efficiency(96, 96, 96, weight_sparsity=0.0,
                                        toggle_rate=0.2)
    assert lo_toggle > e0


def test_energy_scales_quadratically_with_v():
    st = sim.simulate_workload(workloads.Workload(
        "g", (workloads.Op("g", M=96, K=96, N=96),)), "voltra")
    e6 = sim.energy_pj(st, vdd=0.6)
    e12 = sim.energy_pj(st, vdd=1.0)
    # dynamic part scales ~(1/0.6)^2 = 2.78; static energy shrinks with
    # runtime (higher f) and dram is unscaled, so the blend sits between
    assert 1.4 < e12 / e6 < 2.9
