"""Opt-in int8 KV cache: approximate decode equivalence + dtype checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api

# interpret-mode model/kernel tests: minutes on a throttled CPU
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "seamless-m4t-large-v2"])
def test_int8_cache_decode_tracks_bf16(arch):
    cfg16 = get_smoke_config(arch)
    cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="int8", kv_scale=8.0)
    params = api.init_params(cfg16, jax.random.key(0))
    T, prefix = 12, 6
    toks = jax.random.randint(jax.random.key(1), (1, T), 0, cfg16.vocab)
    batch = {"tokens": toks[:, :prefix]}
    if cfg16.is_encdec:
        batch["enc_embeds"] = jax.random.normal(
            jax.random.key(2), (1, prefix, cfg16.d_model),
            jnp.float32).astype(cfg16.dtype)

    outs = {}
    for name, cfg in (("bf16", cfg16), ("int8", cfg8)):
        lg, cache, pos = api.prefill(cfg, params, batch, max_len=T + 4)
        if name == "int8":
            # the cache really is int8
            kleaf = cache["scan"]["0"]["k"] if cache["scan"] else \
                cache["tail"][0]["k"]
            assert kleaf.dtype == jnp.int8
        seq = [lg]
        for t in range(prefix, T):
            lg, cache = api.decode_step(cfg, params, cache,
                                        toks[:, t:t + 1], pos)
            pos = pos + 1
            seq.append(lg)
        outs[name] = np.stack([np.asarray(x, np.float32) for x in seq])

    # int8 cache is lossy but must track bf16 logits closely and produce
    # the same greedy tokens nearly everywhere
    err = np.abs(outs["bf16"] - outs["int8"]).max()
    assert err < 0.7, err
    agree = (outs["bf16"].argmax(-1) == outs["int8"].argmax(-1)).mean()
    assert agree >= 0.8, agree


def test_int8_cache_halves_bytes():
    cfg16 = get_smoke_config("yi-6b")
    cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="int8")
    c16 = api.cache_shapes(cfg16, 2, 64)
    c8 = api.cache_shapes(cfg8, 2, 64)

    def total(c):
        return sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(c))

    assert total(c8) * 2 == total(c16)
