"""Elastic restart: a checkpoint saved under one mesh restores onto a
DIFFERENT mesh shape (cross-mesh resharding), bitwise. Runs in a
subprocess with 4 forced host devices."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import ckpt

    # save under a (2 data, 2 model) mesh
    mesh_a = jax.make_mesh((2, 2), ("data", "model"))
    w = jnp.arange(64.0, dtype=jnp.bfloat16).reshape(8, 8)
    state = {"w": jax.device_put(
        w, NamedSharding(mesh_a, P("data", "model")))}
    ckpt.save("/tmp/elastic_ck/step_00000001", state, extra={"step": 1})

    # restore under a (4 data, 1 model) mesh — a different pod count
    mesh_b = jax.make_mesh((4, 1), ("data", "model"))
    sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
    like = {"w": np.zeros((8, 8), np.float32)}  # also a dtype change
    restored, extra = ckpt.restore("/tmp/elastic_ck/step_00000001", like,
                                   shardings=sh_b)
    assert extra["step"] == 1
    np.testing.assert_array_equal(
        np.asarray(restored["w"]), np.arange(64.0).reshape(8, 8))
    assert restored["w"].sharding == sh_b["w"]
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_cross_mesh_restore():
    # The child MUST pin JAX_PLATFORMS=cpu: without it jax probes the TPU
    # backend (libtpu ships in this image) and blocks for minutes before
    # falling back — the original stripped env dropped the variable and
    # died on TimeoutExpired. The forced 4-device view composes with cpu.
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=900, env=env)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
