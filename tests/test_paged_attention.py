"""Paged flash-decode kernel: dense-MHA equivalence across page sizes,
ragged per-request lengths, preemption-reshuffled block tables, int8
pools, and an end-to-end engine check on the kernel path.

The oracle chain: ops.paged_attention (in-kernel block-table gather) ==
ref.paged_attention_ref (dense gather + masked softmax) == ref.mha_ref
(plain dense attention on the contiguously laid-out cache). All
comparisons are fp32-tolerance — the kernel's per-page online softmax
reorders the accumulation vs the one-shot dense softmax, so bit equality
is not the contract (see test_kv_cache.py for the exact-token bookkeeping
tests, which pin the gather path)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.runtime.kv_cache import SCRATCH_PAGE, PageAllocator


def _paged_case(key, B, H, KV, D, page, n_blocks, lengths, dtype=jnp.float32,
                shuffle_key=None):
    """Build (q, pools, block_table, dense_k, dense_v) where request b's
    tokens 0..lengths[b]-1 are laid out contiguously in dense_k/v and
    scattered page-by-page into the pools via a (optionally shuffled)
    block table. Unowned table entries point at the scratch page."""
    P = 1 + B * n_blocks                       # page 0 = scratch
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    S = n_blocks * page
    dense_k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    dense_v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    phys = np.arange(1, P, dtype=np.int32)
    if shuffle_key is not None:
        phys = np.asarray(jax.random.permutation(shuffle_key, phys))
    table = np.full((B, n_blocks), SCRATCH_PAGE, np.int32)
    kp = np.zeros((P, page, KV, D), np.float32)
    vp = np.zeros((P, page, KV, D), np.float32)
    nxt = 0
    for b in range(B):
        for j in range(-(-int(lengths[b]) // page)):
            pid = int(phys[nxt]); nxt += 1
            table[b, j] = pid
            kp[pid] = np.asarray(dense_k[b, j * page:(j + 1) * page])
            vp[pid] = np.asarray(dense_v[b, j * page:(j + 1) * page])
    return (q, jnp.asarray(kp).astype(dtype), jnp.asarray(vp).astype(dtype),
            jnp.asarray(table), dense_k.astype(dtype), dense_v.astype(dtype))


@pytest.mark.parametrize("page", [8, 16, 64])
def test_matches_dense_mha_across_page_sizes(page):
    """Kernel output == plain dense MHA over the contiguous cache, for
    every page size the serving engine uses."""
    B, H, KV, D, n_blocks = 3, 8, 2, 32, 128 // page
    lengths = [5, 97, 128][:B]
    lengths = [min(n, n_blocks * page) for n in lengths]
    q, kp, vp, table, dk, dv = _paged_case(
        jax.random.key(page), B, H, KV, D, page, n_blocks, lengths)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    for b in range(B):
        want = ref.mha_ref(q[b][None, None], dk[b][None], dv[b][None],
                           causal=False, kv_valid=lengths[b])[0, 0]
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want),
                                   rtol=3e-3, atol=3e-3)


def test_ragged_lengths_ignore_pool_garbage():
    """Positions past each request's length — including whole scratch-page
    blocks — must contribute zero probability mass."""
    B, H, KV, D, page, n_blocks = 4, 4, 4, 16, 8, 4
    lengths = [1, 7, 9, 32]
    key = jax.random.key(1)
    q, kp, vp, table, dk, dv = _paged_case(key, B, H, KV, D, page, n_blocks,
                                           lengths)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    # poison everything the lengths say is dead: unwritten pool rows AND
    # the scratch page; output must not move at all
    kp2 = kp.at[SCRATCH_PAGE].set(1e4)
    vp2 = vp.at[SCRATCH_PAGE].set(1e4)
    for b, n in enumerate(lengths):
        blk, off = n // page, n % page
        if off:
            kp2 = kp2.at[table[b, blk], off:].set(1e4)
            vp2 = vp2.at[table[b, blk], off:].set(1e4)
    got2 = ops.paged_attention(q, kp2, vp2, table, jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_preemption_reshuffled_block_table():
    """After preempt + resume the allocator hands back DIFFERENT physical
    pages (LIFO free list); same logical contents under a reshuffled
    table must give identical outputs."""
    B, H, KV, D, page, n_blocks = 3, 6, 3, 16, 8, 4
    lengths = [9, 17, 25]
    key = jax.random.key(2)
    q, kp1, vp1, t1, _, _ = _paged_case(key, B, H, KV, D, page, n_blocks,
                                        lengths)
    q2, kp2, vp2, t2, _, _ = _paged_case(key, B, H, KV, D, page, n_blocks,
                                         lengths,
                                         shuffle_key=jax.random.key(3))
    assert not np.array_equal(np.asarray(t1), np.asarray(t2))
    a = ops.paged_attention(q, kp1, vp1, t1, jnp.asarray(lengths))
    b = ops.paged_attention(q2, kp2, vp2, t2, jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_allocator_tables_drive_kernel():
    """Reuse the PageAllocator harness: allocate/extend/free/re-allocate,
    then run the kernel on the resulting (fragmented) tables."""
    page, n_blocks = 8, 4
    B, H, KV, D = 2, 4, 2, 16
    a = PageAllocator(2 * n_blocks, page)
    a.allocate(0, 12)                 # 2 pages
    a.allocate(1, 20)                 # 3 pages
    a.free_request(0)                 # rid 0 preempted
    a.allocate(2, 10)                 # resumes into rid 0's LIFO'd pages
    a.check_no_aliasing()
    lengths = [a.tokens(2), a.tokens(1)]
    rows = np.full((B, n_blocks), SCRATCH_PAGE, np.int32)
    for i, rid in enumerate((2, 1)):
        t = a.block_table(rid)
        rows[i, :len(t)] = t
    key = jax.random.key(4)
    kp = jax.random.normal(key, (1 + 2 * n_blocks, page, KV, D), jnp.float32)
    vp = jax.random.normal(jax.random.key(5), kp.shape, jnp.float32)
    q = jax.random.normal(jax.random.key(6), (B, H, D), jnp.float32)
    got = ops.paged_attention(q, kp, vp, jnp.asarray(rows),
                              jnp.asarray(lengths))
    want = ref.paged_attention_ref(q, kp, vp, jnp.asarray(rows),
                                   jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


def test_int8_pool_dequant_in_kernel():
    B, H, KV, D, page, n_blocks = 2, 8, 2, 32, 16, 2
    lengths = [13, 32]
    q, kp, vp, table, _, _ = _paged_case(jax.random.key(7), B, H, KV, D,
                                         page, n_blocks, lengths)
    scale = 8.0
    kq = jnp.clip(jnp.round(kp * 127 / scale), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(vp * 127 / scale), -127, 127).astype(jnp.int8)
    got = ops.paged_attention(q, kq, vq, table, jnp.asarray(lengths),
                              kv_scale=scale)
    want = ref.paged_attention_ref(q, kq, vq, table, jnp.asarray(lengths),
                                   kv_scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


@settings(max_examples=10, deadline=None)
@given(
    page=st.sampled_from([8, 16]),
    n_blocks=st.integers(min_value=1, max_value=4),
    kv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    seed=st.integers(min_value=0, max_value=2 ** 16),
    data=st.data(),
)
def test_property_kernel_matches_ref(page, n_blocks, kv, group, seed, data):
    """Property: for random shapes, tables and ragged lengths, the kernel
    matches the dense-gather oracle to fp32 tolerance. Skips cleanly when
    hypothesis is absent (tests/conftest.py stub)."""
    B, D = 2, 16
    lengths = [data.draw(st.integers(min_value=1,
                                     max_value=page * n_blocks))
               for _ in range(B)]
    q, kp, vp, table, _, _ = _paged_case(
        jax.random.key(seed), B, kv * group, kv, D, page, n_blocks, lengths,
        shuffle_key=jax.random.key(seed + 1))
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    want = ref.paged_attention_ref(q, kp, vp, table, jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# Multi-token query blocks (speculative verify: q rows per request > 1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("page", [8, 16, 64])
@pytest.mark.parametrize("T", [2, 5])
def test_multi_token_block_matches_dense_mha(page, T):
    """A T-row query block must equal T independent causal rows of dense
    MHA: row t (absolute position base + t) sees exactly base + t + 1
    keys. Lengths chosen so blocks straddle page boundaries (base % page
    walks the whole row range) across every serving page size."""
    B, H, KV, D, n_blocks = 3, 8, 2, 32, 128 // page
    lengths = [T + 1, page + T // 2 + 1, 2 * page + T][:B]   # incl. T rows
    lengths = [min(n, n_blocks * page) for n in lengths]
    key = jax.random.key(page + T)
    q, kp, vp, table, dk, dv = _paged_case(
        key, B, T * H, KV, D, page, n_blocks, lengths)
    # _paged_case builds (B, T*H, D) q; reinterpret as (B, T, H, D) rows
    q = q.reshape(B, T, H, D)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    assert got.shape == (B, T, H, D)
    for b in range(B):
        for t in range(T):
            want = ref.mha_ref(q[b, t][None, None], dk[b][None], dv[b][None],
                               causal=False,
                               kv_valid=lengths[b] - T + t + 1)[0, 0]
            np.testing.assert_allclose(np.asarray(got[b, t]),
                                       np.asarray(want),
                                       rtol=3e-3, atol=3e-3)


def test_multi_token_block_matches_paged_ref():
    """Kernel vs the generalized dense-gather oracle on ragged lengths and
    a shuffled (preemption-shaped) block table."""
    B, H, KV, D, page, n_blocks, T = 3, 6, 3, 16, 8, 4, 3
    lengths = [4, 17, 30]
    q, kp, vp, table, _, _ = _paged_case(
        jax.random.key(11), B, T * H, KV, D, page, n_blocks, lengths,
        shuffle_key=jax.random.key(12))
    q = q.reshape(B, T, H, D)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    want = ref.paged_attention_ref(q, kp, vp, table, jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


def test_multi_token_rows_ignore_pool_garbage():
    """Rows past each query row's causal horizon — including the rows the
    block itself occupies — must contribute zero probability mass: row t
    may see rows 0..base+t, never base+t+1..base+T-1."""
    B, H, KV, D, page, n_blocks, T = 2, 4, 2, 16, 8, 3, 4
    lengths = [6, 21]
    key = jax.random.key(13)
    q, kp, vp, table, _, _ = _paged_case(key, B, T * H, KV, D, page,
                                         n_blocks, lengths)
    q = q.reshape(B, T, H, D)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    # poison everything at positions >= each row's own horizon is not
    # possible per-row in one pool, but poisoning past lengths[b]-1 (the
    # LAST row's horizon) plus the scratch page must leave every row
    # unchanged; per-row causality is pinned by the dense-mha test above
    kp2, vp2 = kp.at[SCRATCH_PAGE].set(1e4), vp.at[SCRATCH_PAGE].set(1e4)
    for b, n in enumerate(lengths):
        blk, off = n // page, n % page
        if off:
            kp2 = kp2.at[table[b, blk], off:].set(1e4)
            vp2 = vp2.at[table[b, blk], off:].set(1e4)
    got2 = ops.paged_attention(q, kp2, vp2, table, jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))


def test_single_token_block_is_bitwise_the_3d_path():
    """(B, 1, H, D) q must reduce EXACTLY to the (B, H, D) kernel — the
    T=1 serving path pays nothing for the generalization."""
    B, H, KV, D, page, n_blocks = 3, 8, 2, 32, 16, 4
    lengths = [5, 33, 64]
    q, kp, vp, table, _, _ = _paged_case(jax.random.key(14), B, H, KV, D,
                                         page, n_blocks, lengths)
    a = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    b = ops.paged_attention(q[:, None], kp, vp, table, jnp.asarray(lengths))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b[:, 0]))


def test_multi_token_int8_pool():
    B, H, KV, D, page, n_blocks, T = 2, 8, 2, 32, 16, 2, 3
    lengths = [13, 32]
    q, kp, vp, table, _, _ = _paged_case(jax.random.key(15), B, T * H, KV,
                                         D, page, n_blocks, lengths)
    q = q.reshape(B, T, H, D)
    scale = 8.0
    kq = jnp.clip(jnp.round(kp * 127 / scale), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(vp * 127 / scale), -127, 127).astype(jnp.int8)
    got = ops.paged_attention(q, kq, vq, table, jnp.asarray(lengths),
                              kv_scale=scale)
    want = ref.paged_attention_ref(q, kq, vq, table, jnp.asarray(lengths),
                                   kv_scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


@settings(max_examples=10, deadline=None)
@given(
    page=st.sampled_from([8, 16]),
    n_blocks=st.integers(min_value=1, max_value=4),
    t_rows=st.integers(min_value=1, max_value=4),
    group=st.sampled_from([1, 2]),
    seed=st.integers(min_value=0, max_value=2 ** 16),
    data=st.data(),
)
def test_property_multi_token_matches_ref(page, n_blocks, t_rows, group,
                                          seed, data):
    """Property: random shapes, T-row blocks, tables and ragged lengths —
    kernel == dense-gather oracle to fp32 tolerance."""
    B, KV, D = 2, 2, 16
    H = KV * group
    lengths = [data.draw(st.integers(min_value=t_rows,
                                     max_value=page * n_blocks))
               for _ in range(B)]
    q, kp, vp, table, _, _ = _paged_case(
        jax.random.key(seed), B, t_rows * H, KV, D, page, n_blocks, lengths,
        shuffle_key=jax.random.key(seed + 1))
    q = q.reshape(B, t_rows, H, D)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths))
    want = ref.paged_attention_ref(q, kp, vp, table, jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# Sliding windows (hybrid local_attn layers: in-sweep window masking +
# below-window page skipping; ISSUE 5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window,page", [(4, 8), (16, 4), (7, 8), (32, 16)])
def test_windowed_matches_hand_sliced_dense(window, page):
    """Kernel with a window must equal plain dense attention over exactly
    the last `window` keys — across windows smaller than, equal to, and
    straddling the page size."""
    B, H, KV, D, n_blocks = 3, 8, 2, 32, 4
    lengths = [2, page + 1, min(3 * page + 2, n_blocks * page)][:B]
    q, kp, vp, table, dk, dv = _paged_case(
        jax.random.key(window * 100 + page), B, H, KV, D, page, n_blocks,
        lengths)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths),
                              window=window)
    for b, n in enumerate(lengths):
        lo = max(0, n - window)
        want = ref.mha_ref(q[b][None, None], dk[b][None, lo:n],
                           dv[b][None, lo:n], causal=False)[0, 0]
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want),
                                   rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("T", [2, 4])
def test_windowed_multi_token_matches_ref(T):
    """T-row verify blocks under a window: row t sees keys in
    (base + t - window, base + t] — kernel == generalized oracle."""
    B, H, KV, D, page, n_blocks, window = 2, 6, 3, 16, 8, 4, 5
    lengths = [T + 1, 3 * page + T]
    q, kp, vp, table, _, _ = _paged_case(
        jax.random.key(21 + T), B, T * H, KV, D, page, n_blocks, lengths,
        shuffle_key=jax.random.key(22))
    q = q.reshape(B, T, H, D)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths),
                              window=window)
    want = ref.paged_attention_ref(q, kp, vp, table, jnp.asarray(lengths),
                                   window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


def test_windowed_ignores_below_window_pages():
    """Pages entirely below the window are skipped in-grid AND masked
    in-sweep: poisoning every below-window key — including replacing
    whole recycled pages with the scratch page, as the serving engine
    does — must not move the output at all."""
    B, H, KV, D, page, n_blocks, window = 2, 4, 2, 16, 4, 8, 6
    lengths = [13, 29]
    q, kp, vp, table, _, _ = _paged_case(jax.random.key(31), B, H, KV, D,
                                         page, n_blocks, lengths)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths),
                              window=window)
    kp2, vp2 = kp.at[SCRATCH_PAGE].set(1e4), vp.at[SCRATCH_PAGE].set(1e4)
    table2 = np.asarray(table).copy()
    for b, n in enumerate(lengths):
        lo = n - window                      # first visible key position
        for p_ in range(max(lo, 0)):
            kp2 = kp2.at[table[b, p_ // page], p_ % page].set(1e4)
            vp2 = vp2.at[table[b, p_ // page], p_ % page].set(1e4)
        # recycle: whole blocks below the window point at scratch
        dead = max(0, lo) // page
        table2[b, :dead] = SCRATCH_PAGE
    got2 = ops.paged_attention(q, kp2, vp2, table, jnp.asarray(lengths),
                               window=window)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
    got3 = ops.paged_attention(q, kp2, vp2, jnp.asarray(table2),
                               jnp.asarray(lengths), window=window)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got3))


def test_windowed_int8_pool():
    B, H, KV, D, page, n_blocks, window = 2, 8, 2, 32, 8, 3, 10
    lengths = [7, 23]
    q, kp, vp, table, _, _ = _paged_case(jax.random.key(41), B, H, KV, D,
                                         page, n_blocks, lengths)
    scale = 8.0
    kq = jnp.clip(jnp.round(kp * 127 / scale), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(vp * 127 / scale), -127, 127).astype(jnp.int8)
    got = ops.paged_attention(q, kq, vq, table, jnp.asarray(lengths),
                              kv_scale=scale, window=window)
    want = ref.paged_attention_ref(q, kq, vq, table, jnp.asarray(lengths),
                                   kv_scale=scale, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


def test_windowed_gather_oracle_matches_ref():
    """layers.attend_decode with window (ring=False, absolute positions)
    is the paged gather baseline's masking — it must agree with the
    dense-gather oracle for T == 1 and T > 1."""
    from repro.models.layers import attend_decode
    B, H, KV, D, page, n_blocks, window, T = 2, 4, 2, 16, 8, 3, 5, 3
    lengths = [T + 2, 2 * page + T]
    q, kp, vp, table, _, _ = _paged_case(jax.random.key(51), B, T * H, KV,
                                         D, page, n_blocks, lengths)
    q = q.reshape(B, T, H, D)
    kg = kp[table].reshape(B, n_blocks * page, KV, D)
    vg = vp[table].reshape(B, n_blocks * page, KV, D)
    pos = jnp.asarray(lengths) - T          # first new token's position
    got = attend_decode(q, kg, vg, pos, window=window)
    want = ref.paged_attention_ref(q, kp, vp, table, jnp.asarray(lengths),
                                   window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


@settings(max_examples=10, deadline=None)
@given(
    page=st.sampled_from([4, 8]),
    n_blocks=st.integers(min_value=1, max_value=4),
    t_rows=st.integers(min_value=1, max_value=3),
    window=st.integers(min_value=1, max_value=24),
    seed=st.integers(min_value=0, max_value=2 ** 16),
    data=st.data(),
)
def test_property_windowed_matches_ref(page, n_blocks, t_rows, window,
                                       seed, data):
    """Property: random shapes, T-row blocks, windows and ragged lengths
    — windowed kernel == windowed dense-gather oracle."""
    B, KV, D = 2, 2, 16
    H = KV * 2
    lengths = [data.draw(st.integers(min_value=t_rows,
                                     max_value=page * n_blocks))
               for _ in range(B)]
    q, kp, vp, table, _, _ = _paged_case(
        jax.random.key(seed), B, t_rows * H, KV, D, page, n_blocks, lengths,
        shuffle_key=jax.random.key(seed + 1))
    q = q.reshape(B, t_rows, H, D)
    got = ops.paged_attention(q, kp, vp, table, jnp.asarray(lengths),
                              window=window)
    want = ref.paged_attention_ref(q, kp, vp, table, jnp.asarray(lengths),
                                   window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# End-to-end: the serving engine on the kernel path
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_kernel_engine_matches_dense_engine_fp32():
    """With float32 weights the accumulation-order wobble is ~1e-6, far
    below any logit gap — so the kernel-path engine must reproduce the
    dense engine's greedy tokens exactly, through admission, page growth,
    preemption and resume."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import api
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import (DenseServingEngine,
                                       PagedServingEngine, Request)
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"),
                              dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))

    def mk():
        return [Request(rid=0, prompt=[5, 4, 3, 2, 1, 6, 7], max_new=8),
                Request(rid=1, prompt=[1, 2, 3, 4, 5, 6], max_new=8)]

    dense = DenseServingEngine(cfg, params, slots=2, max_len=32)
    want = {r.rid: r.generated
            for r in dense.run_to_completion(mk(), max_steps=60)}

    eng = PagedServingEngine(cfg, params, slots=2, max_len=32, page_size=4,
                             num_pages=4, attn_impl="kernel")
    reqs = mk()
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)
    sched.drain(max_steps=400)
    assert sched.preempted >= 1          # the pool is sized to force it
    assert {r.rid: r.generated for r in reqs} == want
    eng.alloc.check_no_aliasing()
    assert eng.alloc.allocated_pages == 0
