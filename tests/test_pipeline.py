"""GPipe pipeline: numerical equivalence to the sequential stack.

Runs in a subprocess with 4 forced host devices (the main test process
keeps the single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro import compat
    from repro.parallel.pipeline import bubble_fraction, gpipe

    mesh = jax.make_mesh((4,), ("stage",))
    S, M, mb, d = 4, 4, 2, 8     # small: compile time dominates on CPU

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    k = jax.random.key(0)
    params = {"w": jax.random.normal(k, (S, d, d)) * 0.5,
              "b": jnp.zeros((S, d))}
    xs = jax.random.normal(jax.random.key(1), (M, mb, d))

    piped = gpipe(stage_fn, mesh, "stage")
    with compat.set_mesh(mesh):
        got = jax.jit(piped)(params, xs)

    # sequential reference
    want = xs
    for s in range(S):
        want = jax.vmap(lambda x: stage_fn(
            {"w": params["w"][s], "b": params["b"][s]}, x))(want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_gpipe_matches_sequential():
    # The child MUST pin JAX_PLATFORMS=cpu: without it jax probes the TPU
    # backend (libtpu ships in this image) and blocks for minutes before
    # falling back — the original stripped env dropped the variable and
    # died on TimeoutExpired. The forced 4-device view composes with cpu.
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=900, env=env)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
