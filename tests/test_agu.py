"""AGU programming model: descriptor streams vs explicit-im2col oracle,
GEMM coverage properties, and the reshuffler's bank-conflict claim."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import agu


@pytest.mark.parametrize("layout", ["HWC", "C8HWC8"])
@pytest.mark.parametrize("spec", [
    # (H, W, C, R, S, stride): OW must be a multiple of 8 (beat grouping)
    (10, 10, 8, 3, 3, 1),
    (19, 17, 16, 3, 3, 2),
    (12, 12, 32, 5, 5, 1),
    (16, 16, 8, 1, 1, 1),
    (21, 21, 8, 7, 7, 2),
])
def test_im2col_descriptor_matches_oracle(layout, spec):
    """The 6-D affine program must produce exactly the explicit-im2col
    gather stream — 'supporting ... implicit im2col for all convolution
    types, covering arbitrary stride, kernel size, input channel'."""
    H, W, C, R, S, stride = spec
    desc = agu.im2col_descriptor(H=H, W=W, C=C, R=R, S=S, stride=stride,
                                 layout=layout)
    assert agu.addresses(desc) == agu.im2col_reference(
        H=H, W=W, C=C, R=R, S=S, stride=stride, layout=layout)
    assert len(desc.bounds) <= 6      # fits the chip's 6-D AGU


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 2), st.integers(1, 3),
       st.integers(1, 2))
def test_im2col_hypothesis_sweep(mh, mw, r, stride):
    H = r + stride * (3 * mh - 1)                 # OH = 3*mh (any)
    W = r + stride * (8 * mw - 1)                 # OW = 8*mw (beat-aligned)
    desc = agu.im2col_descriptor(H=H, W=W, C=8, R=r, S=r, stride=stride)
    assert agu.addresses(desc) == agu.im2col_reference(
        H=H, W=W, C=8, R=r, S=r, stride=stride)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(1, 16), st.integers(1, 4))
def test_gemm_descriptors_cover_operands(mt, kb, nt):
    """Every input row-beat is visited once per n-tile; every weight word
    once per m-tile (the operand reuse the 3D array exploits)."""
    M, K, N = 8 * mt, 8 * kb, 8 * nt
    d = agu.gemm_descriptors(M, K, N)
    ins = agu.addresses(d["input"])
    ws = agu.addresses(d["weight"])
    n_tiles, m_tiles = N // 8, M // 8
    # input: the full (M x K) int8 matrix in 8-byte words, n_tiles times
    words = {8 * i for i in range(M * K // 8)}
    assert len(ins) == len(words) * n_tiles
    assert set(ins) == words
    # weight: full (N x K) walked m_tiles times
    wwords = {8 * i for i in range(N * K // 8)}
    assert len(ws) == len(wwords) * m_tiles
    assert set(ws) == wwords


def test_reshuffler_kills_intra_beat_conflicts():
    """Sec. II-E quantified: the HWC im2col walk of a C=256 feature map
    collides inside a beat (channel stride aliases the 32-bank map), the
    reshuffled C/8HWC8 walk is conflict-free."""
    spec = dict(H=18, W=18, C=256, R=3, S=3, stride=1)
    hwc = agu.bank_conflict_profile(
        agu.addresses(agu.im2col_descriptor(layout="HWC", **spec)))
    blocked = agu.bank_conflict_profile(
        agu.addresses(agu.im2col_descriptor(layout="C8HWC8", **spec)))
    assert blocked["throughput"] == 1.0           # conflict-free
    # HWC: adjacent pixels are stride*C = 256 B apart -> same bank for
    # all 8 words of a beat -> 8-way serialization
    assert hwc["throughput"] <= 0.13
    assert hwc["worst_multiplicity"] == 8


def test_gemm_weight_stream_is_superbank_friendly():
    """Weight beats walk K-major contiguously: 8 consecutive words = one
    512-bit super-bank line (the coarse-grained channel of Fig. 3b)."""
    d = agu.gemm_descriptors(8, 64, 8)["weight"]
    st_ = agu.addresses(d)
    # within one column (inner 8 beats) addresses advance by 8 bytes
    for j in range(0, 64, 8):
        chunk = st_[j:j + 8]
        assert all(b - a == 8 for a, b in zip(chunk, chunk[1:]))


def test_descriptor_validation():
    with pytest.raises(AssertionError):
        agu.AGUDescriptor(0, (1,) * 7, (1,) * 7)   # > 6-D
    with pytest.raises(AssertionError):
        agu.AGUDescriptor(0, (2, 0), (1, 1))       # zero bound
