"""Fixture tests for repro-lint (src/repro/analysis/lint): per rule, at
least one flagged snippet per sub-pattern, one clean snippet, and proof
the `# repro-lint: disable=` marker is honored; plus CLI-level contracts
(JSON schema, exit codes, baseline, gitignore skipping, and the no-jax
import guarantee the CI lint job relies on).

Deliberately jax-free: the linter is stdlib-ast-only and these tests run
on an interpreter with no jax at all (that IS one of the assertions).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import REGISTRY, lint_source

REPO_ROOT = Path(__file__).resolve().parents[1]


def rules_of(findings):
    return [f.rule for f in findings]


def lint(src, path="pkg/engine.py", rules=None):
    return lint_source(textwrap.dedent(src), path=path, rules=rules)


# --------------------------------------------------------------------------
# registry basics
# --------------------------------------------------------------------------

def test_registry_has_the_contracted_rules():
    assert {"compat-policy", "host-sync", "retrace-hazard",
            "kernel-purity"} <= set(REGISTRY)


# --------------------------------------------------------------------------
# compat-policy
# --------------------------------------------------------------------------

class TestCompatPolicy:
    def test_hasattr_on_jax_flagged(self):
        fs = lint("import jax\nok = hasattr(jax, 'set_mesh')\n",
                  rules=["compat-policy"])
        assert rules_of(fs) == ["compat-policy"] and fs[0].line == 2

    def test_three_arg_getattr_on_pltpu_flagged(self):
        fs = lint(
            "from jax.experimental.pallas import tpu as pltpu\n"
            "cp = getattr(pltpu, 'CompilerParams', None)\n",
            rules=["compat-policy"])
        assert rules_of(fs) == ["compat-policy"]

    def test_version_string_comparison_flagged(self):
        fs = lint("import jax\nold = jax.__version__ < '0.5'\n",
                  rules=["compat-policy"])
        assert rules_of(fs) == ["compat-policy"]

    def test_metadata_version_probe_flagged(self):
        fs = lint(
            "import importlib.metadata\n"
            "v = importlib.metadata.version('jax')\n",
            rules=["compat-policy"])
        assert rules_of(fs) == ["compat-policy"]

    def test_compat_module_itself_exempt(self):
        fs = lint("import jax\nok = hasattr(jax, 'set_mesh')\n",
                  path="src/repro/compat.py", rules=["compat-policy"])
        assert fs == []

    def test_duck_typing_getattr_clean(self):
        # 3-arg getattr on runtime objects is ordinary duck typing
        fs = lint("def f(req):\n    return getattr(req, 'params', None)\n",
                  rules=["compat-policy"])
        assert fs == []

    def test_two_arg_getattr_on_jax_clean(self):
        fs = lint("import jax\ng = getattr(jax, 'jit')\n",
                  rules=["compat-policy"])
        assert fs == []

    def test_suppression_honored(self):
        fs = lint(
            "import jax\n"
            "ok = hasattr(jax, 'x')  # repro-lint: disable=compat-policy\n",
            rules=["compat-policy"])
        assert fs == []


# --------------------------------------------------------------------------
# host-sync
# --------------------------------------------------------------------------

_TRACED_FACTORY = """
    import jax
    import jax.numpy as jnp

    class Engine:
        def __init__(self):
            self._step_fn = jax.jit(self._make_step())

        def _make_step(self):
            def step(tok, pos):
                {body}
            return step
"""


def traced(body):
    lines = textwrap.dedent(body).strip().splitlines()
    pad = "\n".join(" " * 12 + ln for ln in lines)
    return textwrap.dedent(_TRACED_FACTORY).replace(
        " " * 12 + "{body}", pad)


class TestHostSync:
    def test_sync_point_in_engine_code_flagged(self):
        fs = lint("import jax\ndef loop(arr):\n"
                  "    return jax.device_get(arr)\n", rules=["host-sync"])
        assert rules_of(fs) == ["host-sync"]

    def test_item_and_block_until_ready_flagged(self):
        fs = lint("def loop(arr):\n"
                  "    arr.block_until_ready()\n"
                  "    return arr.item()\n", rules=["host-sync"])
        assert rules_of(fs) == ["host-sync", "host-sync"]

    def test_sync_point_scoped_out_of_tests_and_benchmarks(self):
        src = "import jax\ndef timed(x):\n    jax.block_until_ready(x)\n"
        assert lint(src, path="tests/test_x.py",
                    rules=["host-sync"]) == []
        assert lint(src, path="benchmarks/bench.py",
                    rules=["host-sync"]) == []
        assert rules_of(lint(src, path="src/repro/runtime/x.py",
                             rules=["host-sync"])) == ["host-sync"]

    def test_if_on_array_inside_factory_traced_closure(self):
        # the serving-engine idiom: jax.jit(self._make_step()) — the
        # closure the factory returns is traced
        fs = lint(traced("""
            s = jnp.sum(tok)
            if s > 0:
                return pos
            return s
        """), rules=["host-sync"])
        assert rules_of(fs) == ["host-sync"]
        assert "`if` on an array-valued test" in fs[0].message

    def test_while_on_array_flagged(self):
        fs = lint(traced("""
            s = jnp.max(tok)
            while s > 0:
                s = s - 1
            return s
        """), rules=["host-sync"])
        assert any("`while`" in f.message for f in fs)

    def test_coercions_inside_trace_flagged(self):
        fs = lint(traced("""
            s = jnp.sum(tok)
            a = int(s)
            b = float(s + 1)
            c = bool(jnp.any(tok))
            return a, b, c
        """), rules=["host-sync"])
        assert rules_of(fs) == ["host-sync"] * 3

    def test_np_asarray_inside_trace_flagged(self):
        fs = lint(traced("""
            import numpy as np
            s = jnp.sum(tok)
            return np.asarray(s)
        """), rules=["host-sync"])
        assert rules_of(fs) == ["host-sync"]

    def test_device_get_inside_trace_flagged(self):
        fs = lint(traced("""
            s = jnp.sum(tok)
            return jax.device_get(s)
        """), rules=["host-sync"])
        assert len(fs) == 1 and "trace" in fs[0].message

    def test_transitive_helper_within_module_flagged(self):
        fs = lint("""
            import jax
            import jax.numpy as jnp

            def helper(x):
                m = jnp.max(x)
                if m > 0:
                    return m
                return x

            def step(x):
                return helper(x)

            step_fn = jax.jit(step)
        """, rules=["host-sync"])
        assert rules_of(fs) == ["host-sync"]

    def test_shard_map_wrapped_body_flagged(self):
        fs = lint("""
            from repro import compat
            import jax.numpy as jnp

            def body(x):
                s = jnp.sum(x)
                return int(s)

            f = compat.shard_map(body, None, in_specs=(), out_specs=())
        """, rules=["host-sync"])
        assert rules_of(fs) == ["host-sync"]

    def test_static_control_flow_clean(self):
        # host control flow on static values at trace time is the normal
        # closure-building idiom — must NOT flag
        fs = lint(traced("""
            if pos is None:
                pos = 0
            out = jnp.where(tok > 0, tok, pos)
            return out
        """), rules=["host-sync"])
        assert fs == []

    def test_static_jnp_helpers_clean(self):
        fs = lint(traced("""
            if jnp.issubdtype(tok.dtype, jnp.integer):
                tok = tok.astype(jnp.float32)
            return tok
        """), rules=["host-sync"])
        assert fs == []

    def test_host_function_coercions_clean(self):
        fs = lint("def bucket(n):\n    return int(n) * 2\n",
                  rules=["host-sync"])
        assert fs == []

    def test_suppression_honored(self):
        fs = lint(
            "import jax\ndef loop(arr):\n"
            "    # repro-lint: disable=host-sync — the one blessed sync\n"
            "    return jax.device_get(arr)\n", rules=["host-sync"])
        assert fs == []


# --------------------------------------------------------------------------
# retrace-hazard
# --------------------------------------------------------------------------

class TestRetraceHazard:
    def test_jit_per_call_flagged(self):
        fs = lint("""
            import jax

            def serve(x):
                return jax.jit(lambda a: a + 1)(x)
        """, rules=["retrace-hazard"])
        assert rules_of(fs) == ["retrace-hazard"]

    def test_module_level_jit_call_clean(self):
        fs = lint("import jax\ndef f(x):\n    return x\n"
                  "y = jax.jit(f)(3)\n", rules=["retrace-hazard"])
        assert fs == []

    def test_fresh_object_in_static_kwarg_flagged(self):
        fs = lint("""
            import jax

            def f(x, cfg):
                return x

            step = jax.jit(f, static_argnames=("cfg",))

            class Cfg:
                pass

            def serve(x):
                return step(x, cfg=Cfg())
        """, rules=["retrace-hazard"])
        assert rules_of(fs) == ["retrace-hazard"]
        assert "identity-hashed" in fs[0].message

    def test_unhashable_static_positional_flagged(self):
        fs = lint("""
            import jax

            def f(x, shape):
                return x

            step = jax.jit(f, static_argnums=(1,))

            def serve(x):
                return step(x, [1, 2])
        """, rules=["retrace-hazard"])
        assert rules_of(fs) == ["retrace-hazard"]
        assert "unhashable" in fs[0].message

    def test_constant_static_operand_clean(self):
        fs = lint("""
            import jax

            def f(x, cfg):
                return x

            step = jax.jit(f, static_argnames=("cfg",))
            CFG = object()

            def serve(x):
                return step(x, cfg=CFG)
        """, rules=["retrace-hazard"])
        assert fs == []

    def test_self_capture_in_jitted_closure_flagged(self):
        fs = lint("""
            import jax

            class Engine:
                def __init__(self):
                    self.scale = 2.0
                    self._fn = jax.jit(self._make())

                def _make(self):
                    def step(x):
                        return x * self.scale
                    return step
        """, rules=["retrace-hazard"])
        assert rules_of(fs) == ["retrace-hazard"]
        assert "self.scale" in fs[0].message

    def test_hoisted_factory_local_clean(self):
        # the serving idiom: read self BEFORE the closure
        fs = lint("""
            import jax

            class Engine:
                def __init__(self):
                    self.scale = 2.0
                    self._fn = jax.jit(self._make())

                def _make(self):
                    scale = self.scale
                    def step(x):
                        return x * scale
                    return step
        """, rules=["retrace-hazard"])
        assert fs == []

    def test_rule_scoped_out_of_tests(self):
        src = ("import jax\ndef t(x):\n"
               "    return jax.jit(lambda a: a)(x)\n")
        assert lint(src, path="tests/test_y.py",
                    rules=["retrace-hazard"]) == []

    def test_suppression_honored(self):
        fs = lint("""
            import jax

            def serve(x):
                # repro-lint: disable=retrace-hazard — one-shot warmup
                return jax.jit(lambda a: a + 1)(x)
        """, rules=["retrace-hazard"])
        assert fs == []


# --------------------------------------------------------------------------
# kernel-purity
# --------------------------------------------------------------------------

_KERNEL = """
    import functools
    import numpy as np
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kern(x_ref, o_ref, *, page):
        {body}

    call = pl.pallas_call(functools.partial(kern, page=8))
"""


def kernel(body):
    lines = textwrap.dedent(body).strip().splitlines()
    pad = "\n".join(" " * 4 + ln for ln in lines)
    return textwrap.dedent(_KERNEL).replace(" " * 4 + "{body}", pad)


class TestKernelPurity:
    def test_numpy_call_flagged(self):
        fs = lint(kernel("o_ref[...] = np.zeros(3)\n"),
                  rules=["kernel-purity"])
        assert rules_of(fs) == ["kernel-purity"]

    def test_print_flagged(self):
        fs = lint(kernel("print('dbg')\no_ref[...] = x_ref[...]\n"),
                  rules=["kernel-purity"])
        assert rules_of(fs) == ["kernel-purity"]
        assert "pl.debug_print" in fs[0].message

    def test_host_callback_flagged(self):
        fs = lint(kernel("""
            import jax
            jax.debug.callback(lambda: None)
            o_ref[...] = x_ref[...]
        """), rules=["kernel-purity"])
        assert rules_of(fs) == ["kernel-purity"]

    def test_reduction_over_dynamic_slice_flagged(self):
        fs = lint(kernel("""
            n = x_ref[0]
            o_ref[...] = jnp.sum(x_ref[1:n])
        """), rules=["kernel-purity"])
        assert rules_of(fs) == ["kernel-purity"]
        assert "dynamically-shaped" in fs[0].message

    def test_pl_ds_with_traced_size_flagged(self):
        fs = lint(kernel("""
            n = x_ref[0]
            o_ref[...] = x_ref[pl.ds(0, n)]
        """), rules=["kernel-purity"])
        assert rules_of(fs) == ["kernel-purity"]

    def test_static_kernel_clean(self):
        # masked static-shape reduction: the blessed idiom
        fs = lint(kernel("""
            i = pl.program_id(0)
            x = x_ref[...]
            mask = jnp.arange(x.shape[0]) < page
            o_ref[...] = jnp.sum(jnp.where(mask, x, 0.0))
            y = x_ref[pl.ds(i * page, page)]
        """), rules=["kernel-purity"])
        assert fs == []

    def test_numpy_outside_kernel_clean(self):
        fs = lint("import numpy as np\n"
                  "def host():\n    return np.zeros(3)\n",
                  rules=["kernel-purity"])
        assert fs == []

    def test_suppression_honored(self):
        fs = lint(kernel("""
            # repro-lint: disable=kernel-purity — interpret-only debug
            print('dbg')
            o_ref[...] = x_ref[...]
        """), rules=["kernel-purity"])
        assert fs == []


# --------------------------------------------------------------------------
# CLI contracts (subprocess: exit codes, JSON schema, baseline, no-jax)
# --------------------------------------------------------------------------

def run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        cwd=cwd, env=env, capture_output=True, text=True, timeout=120)


@pytest.fixture
def seeded_tree(tmp_path):
    (tmp_path / "pkg").mkdir()
    (tmp_path / "pkg" / "bad.py").write_text(
        "import jax\nok = hasattr(jax, 'jit')\n")
    (tmp_path / "pkg" / "good.py").write_text("X = 1\n")
    return tmp_path


def test_cli_fails_on_seeded_violation(seeded_tree):
    # the CI lint-job contract: a violation is a red build (exit 1) with
    # the machine-readable `path:line: rule message` finding format
    r = run_cli(["pkg"], cwd=seeded_tree)
    assert r.returncode == 1
    assert "pkg/bad.py:2: compat-policy" in r.stdout


def test_cli_clean_tree_exits_zero(seeded_tree):
    (seeded_tree / "pkg" / "bad.py").unlink()
    r = run_cli(["pkg"], cwd=seeded_tree)
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_json_schema(seeded_tree):
    r = run_cli(["pkg", "--json"], cwd=seeded_tree)
    assert r.returncode == 1
    report = json.loads(r.stdout)
    assert report["version"] == 1 and report["tool"] == "repro-lint"
    assert set(report) >= {"files", "suppressed", "baselined", "counts",
                           "rules", "findings"}
    assert report["counts"] == {"compat-policy": 1}
    f = report["findings"][0]
    assert set(f) == {"path", "line", "col", "rule", "message"}
    assert f["path"] == "pkg/bad.py" and f["line"] == 2


def test_cli_out_writes_report_file(seeded_tree):
    r = run_cli(["pkg", "--out", "report.json"], cwd=seeded_tree)
    assert r.returncode == 1
    report = json.loads((seeded_tree / "report.json").read_text())
    assert report["counts"] == {"compat-policy": 1}


def test_cli_baseline_grandfathers_and_ratchets(seeded_tree):
    r = run_cli(["pkg", "--write-baseline"], cwd=seeded_tree)
    assert r.returncode == 0
    base = (seeded_tree / ".repro-lint-baseline").read_text()
    assert "pkg/bad.py|compat-policy|" in base
    # baselined finding no longer fails the run...
    r = run_cli(["pkg"], cwd=seeded_tree)
    assert r.returncode == 0 and "1 baselined" in r.stderr
    # ...but a NEW violation still does (the ratchet)
    (seeded_tree / "pkg" / "worse.py").write_text(
        "import jax\nv = jax.__version__\n")
    r = run_cli(["pkg"], cwd=seeded_tree)
    assert r.returncode == 1


def test_cli_unknown_rule_is_usage_error(seeded_tree):
    r = run_cli(["pkg", "--rule", "nope"], cwd=seeded_tree)
    assert r.returncode == 2


def test_cli_list_rules(tmp_path):
    r = run_cli(["--list-rules"], cwd=tmp_path)
    assert r.returncode == 0
    for rid in ("compat-policy", "host-sync", "retrace-hazard",
                "kernel-purity"):
        assert rid in r.stdout


def test_cli_skips_gitignored_and_pycache(seeded_tree):
    (seeded_tree / ".gitignore").write_text("generated/\n*.pyc\n")
    (seeded_tree / "generated").mkdir()
    (seeded_tree / "generated" / "bad2.py").write_text(
        "import jax\nv = jax.__version__\n")
    pyc = seeded_tree / "pkg" / "__pycache__"
    pyc.mkdir()
    (pyc / "bad3.py").write_text("import jax\nv = jax.__version__\n")
    r = run_cli(["."], cwd=seeded_tree)
    assert r.returncode == 1
    assert "bad2.py" not in r.stdout and "bad3.py" not in r.stdout


def test_lint_package_never_imports_jax(tmp_path):
    # the CI lint job runs on an interpreter WITHOUT jax; the linter
    # must neither import jax nor need it transitively
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    r = subprocess.run(
        [sys.executable, "-c",
         "import sys\n"
         "import repro.analysis.lint as lint\n"
         "lint.lint_source('import jax\\nx = hasattr(jax, \"jit\")\\n')\n"
         "assert 'jax' not in sys.modules, 'linter imported jax'\n"
         "print('no-jax-ok')"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "no-jax-ok" in r.stdout
