"""C3: tiling planner + arena allocator — hypothesis properties + anchors."""
import math

import pytest
from hypothesis import given, strategies as st

from repro.core import pdma, tiling, workloads
from repro.core.accel import SEPARATED_MEM, VOLTRA
from repro.core.workloads import Op

dims = st.integers(min_value=1, max_value=8192)


@given(dims, dims, dims)
def test_shared_plan_fits_budget(M, K, N):
    p = tiling.plan_op(Op("x", M=M, K=K, N=N), "shared")
    assert p.footprint <= VOLTRA.mem_bytes


@given(dims, dims, dims)
def test_separated_plan_fits_buffers(M, K, N):
    p = tiling.plan_op(Op("x", M=M, K=K, N=N), "separated")
    spill = p.k_split
    assert 2 * p.tm * p.tk <= SEPARATED_MEM.budget("input")
    assert 2 * p.tk * p.tn <= SEPARATED_MEM.budget("weight")
    out_b = p.tm * p.tn * (4 if spill else 1)
    assert out_b <= SEPARATED_MEM.budget("output")


@given(dims, dims, dims)
def test_dma_lower_bound(M, K, N):
    """Every operand must cross the DMA at least once (compulsory
    traffic)."""
    def r8(x):
        return 8 * math.ceil(x / 8)
    for arena in ("shared", "separated"):
        p = tiling.plan_op(Op("x", M=M, K=K, N=N), arena)
        assert p.dma_in >= r8(M) * r8(K)
        assert p.dma_w >= r8(K) * r8(N)
        assert p.dma_out >= r8(M) * r8(N)


@given(dims, dims, dims)
def test_shared_never_more_dma_than_separated(M, K, N):
    """PDMA's whole point: the single budget dominates the split one
    (any separated-feasible tiling is shared-feasible: 2(in+w)+out <=
    in_buf + w_buf + out_buf = the same 128 KB)."""
    op = Op("x", M=M, K=K, N=N)
    s = tiling.plan_op(op, "shared").dma_total
    p = tiling.plan_op(op, "separated").dma_total
    assert s <= p
    n = tiling.plan_op_naive_separated(op).dma_total
    assert s <= n


@given(dims, dims, dims)
def test_naive_separated_fits_buffers(M, K, N):
    op = Op("x", M=M, K=K, N=N)
    p = tiling.plan_op_naive_separated(op)
    assert 2 * p.tm * p.tk <= SEPARATED_MEM.budget("input")
    assert 2 * p.tk * p.tn <= SEPARATED_MEM.budget("weight")


def test_fig1c_resnet50_memory_saving():
    """Paper Fig. 1(c): shared memory needs ~50% less provisioned memory
    for the same ResNet50 tiling."""
    r = tiling.memory_usage_report(workloads.resnet50())
    assert 0.35 <= r["saving_frac"] <= 0.6


def test_mha_access_saving_brackets_paper():
    """Paper Fig. 4(c): 14.3% fewer total accesses. Our model brackets it
    between the X-resident (conservative) and X-refetch baselines."""
    r = pdma.mha_access_counts()
    assert r["saving_frac"] > 0.08
    assert r["saving_frac_refetch"] > 0.143 > r["saving_frac"]
    assert r["peak_arena_bytes"] <= r["arena_capacity"]


# ---------------------------------------------------------------------------
# Arena allocator
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(1, 40_000), min_size=1, max_size=12))
def test_arena_alloc_no_overlap(sizes):
    a = pdma.Arena()
    placed = 0
    for i, s in enumerate(sizes):
        try:
            a.alloc(f"b{i}", s)
            placed += 1
        except pdma.ArenaError:
            break
    assert not a.overlaps()
    assert a.used <= a.capacity


@given(st.lists(st.tuples(st.integers(1, 30_000), st.booleans()),
                min_size=1, max_size=20))
def test_arena_free_reclaims(ops_list):
    """Alloc/free interleavings never corrupt the arena; freeing makes the
    space allocatable again."""
    a = pdma.Arena()
    live = []
    for i, (size, do_free) in enumerate(ops_list):
        if do_free and live:
            a.free(live.pop())
        else:
            try:
                a.alloc(f"b{i}", size)
                live.append(f"b{i}")
            except pdma.ArenaError:
                pass
        assert not a.overlaps()
    for name in live:
        a.free(name)
    assert a.used == 0
    # after freeing everything, a full-capacity alloc must succeed
    a.alloc("big", a.capacity)


def test_arena_exact_fill():
    a = pdma.Arena()
    a.alloc("x", a.capacity)
    with pytest.raises(pdma.ArenaError):
        a.alloc("y", 1)
    a.free("x")
    a.alloc("y", a.capacity // 2)
    a.alloc("z", a.capacity // 2)
