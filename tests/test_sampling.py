"""Decode policies (ISSUE 9): the fused mask->top-k->top-p->categorical
sampler vs numpy references, per-request PRNG determinism (same (seed,
rid, idx) -> same token across engines, attn impls and preemption), the
one-trace-per-policy-mix contract asserted via the step_traces/spec_traces
telemetry, the draft-model drafter's paged-cache sync invariants, loud
failure modes of the policy/drafter plumbing, and a slow chi-square check
that rejection-sampled speculative verification preserves the sampling
distribution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.sampling import (GREEDY, NEG_FILTER, SamplingParams,
                                    policy_operands, sample_rows,
                                    scale_mask, summarize)

# ---------------------------------------------------------------------------
# SamplingParams (pure host-side)
# ---------------------------------------------------------------------------


def test_params_validate_bounds():
    SamplingParams().validate()
    SamplingParams(temperature=1.5, top_k=3, top_p=0.5, seed=7).validate()
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1).validate()
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1).validate()
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0).validate()
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.2).validate()
    assert GREEDY.is_greedy
    assert not SamplingParams(temperature=0.5).is_greedy
    assert summarize([GREEDY, None, SamplingParams(temperature=1.0)]) \
        == "1 greedy / 1 sampled"


# ---------------------------------------------------------------------------
# scale_mask vs a straight-line numpy reference
# ---------------------------------------------------------------------------


def _np_scale_mask(row, temp, top_k, top_p):
    z = row.astype(np.float64)
    if temp > 0:
        z = z / temp
    if top_k > 0:
        kth = np.sort(z)[::-1][min(top_k, len(z)) - 1]
        z = np.where(z >= kth, z, NEG_FILTER)
    if top_p < 1.0:
        srt = np.sort(z)[::-1]
        p = np.exp(srt - srt.max())
        p = p / p.sum()
        keep = (np.cumsum(p) - p) < top_p
        pth = srt[max(int(keep.sum()), 1) - 1]
        z = np.where(z >= pth, z, NEG_FILTER)
    return z


def test_scale_mask_matches_numpy_reference():
    rng = np.random.default_rng(0)
    cases = [(0.0, 0, 1.0), (1.0, 4, 1.0), (0.7, 0, 0.6), (1.3, 5, 0.8),
             (2.0, 1, 0.3), (0.5, 16, 1.0), (1.0, 3, 0.5)]
    logits = (3 * rng.normal(size=(len(cases), 16))).astype(np.float32)
    z = np.asarray(scale_mask(
        jnp.asarray(logits),
        jnp.asarray([c[0] for c in cases], jnp.float32),
        jnp.asarray([c[1] for c in cases], jnp.int32),
        jnp.asarray([c[2] for c in cases], jnp.float32)))
    for i, (t, k, p) in enumerate(cases):
        ref = _np_scale_mask(logits[i], t, k, p)
        kept, ref_kept = z[i] > NEG_FILTER / 2, ref > NEG_FILTER / 2
        assert kept.tolist() == ref_kept.tolist(), (i, t, k, p)
        # the top-1 token always survives both filters (greedy exactness)
        assert kept[np.argmax(logits[i])]
        np.testing.assert_allclose(z[i][kept], ref[ref_kept], rtol=1e-5)


def test_greedy_rows_are_exact_argmax():
    # temp == 0 rows reduce to the pre-ISSUE-9 argmax regardless of
    # top_k / top_p / seed — the fused program's greedy contract
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(5, 32)).astype(np.float32)
    pol = policy_operands(
        [GREEDY, SamplingParams(top_k=3), SamplingParams(top_p=0.4),
         None, SamplingParams(seed=123)],
        rids=[0, 1, 2, 3, 4], idxs=[0, 5, 9, 2, 7], default_seed=0)
    toks = np.asarray(sample_rows(jnp.asarray(logits), pol))
    assert toks.tolist() == np.argmax(logits, -1).tolist()


# ---------------------------------------------------------------------------
# PRNG derivation: tokens are a pure function of (seed, rid, idx)
# ---------------------------------------------------------------------------


def test_sampled_draw_is_pure_function_of_seed_rid_idx():
    rng = np.random.default_rng(2)
    rows = rng.normal(size=(4, 64)).astype(np.float32)
    rows[3] = rows[1]          # rows 1 and 3 share logits AND key below
    logits = jnp.asarray(rows)
    p = SamplingParams(temperature=1.0, seed=5)
    pol = policy_operands([p] * 4, rids=[0, 1, 0, 1], idxs=[3, 3, 4, 3],
                          default_seed=0)
    a = np.asarray(sample_rows(logits, pol))
    assert a.tolist() == np.asarray(sample_rows(logits, pol)).tolist()
    # row 3 duplicates row 1's (seed, rid, idx): identical draw, while
    # rows 0/1 (rid differs) and 0/2 (idx differs) are independent keys
    assert a[3] == a[1]
    # `offset` shifts the generated-token index: the verify step's row at
    # idx + t must consume the same key the plain step would at idx = t
    pol_o = policy_operands([p] * 4, rids=[0, 1, 0, 1], idxs=[2, 2, 3, 2],
                            default_seed=0)
    assert np.asarray(sample_rows(logits, pol_o, offset=1)).tolist() \
        == a.tolist()


def test_sampled_marginals_match_softmax():
    # frequencies over 4000 independent draws (distinct idx) land within
    # 4 sigma of softmax(logits) per bin — deterministic given the seed
    V, N = 8, 4000
    row = np.asarray([0.0, 1.0, 2.0, -1.0, 0.5, 1.5, -2.0, 0.25],
                     np.float32)
    p_ref = np.exp(row) / np.exp(row).sum()
    pol = policy_operands([SamplingParams(temperature=1.0, seed=11)] * N,
                          rids=[0] * N, idxs=list(range(N)), default_seed=0)
    toks = np.asarray(sample_rows(
        jnp.broadcast_to(jnp.asarray(row), (N, V)), pol))
    counts = np.bincount(toks, minlength=V)
    for v in range(V):
        sd = np.sqrt(N * p_ref[v] * (1 - p_ref[v]))
        assert abs(counts[v] - N * p_ref[v]) <= 4 * sd + 1, (v, counts)


def test_topk_sampling_support_and_renormalization():
    # top_k=3 keeps tokens {2, 5, 1} only, with mass renormalized on them
    V, N = 8, 3000
    row = np.asarray([0.0, 1.0, 2.0, -1.0, 0.5, 1.5, -2.0, 0.25],
                     np.float32)
    keep = np.argsort(row)[::-1][:3]
    p_ref = np.zeros(V)
    p_ref[keep] = np.exp(row[keep]) / np.exp(row[keep]).sum()
    pol = policy_operands(
        [SamplingParams(temperature=1.0, top_k=3, seed=17)] * N,
        rids=[0] * N, idxs=list(range(N)), default_seed=0)
    toks = np.asarray(sample_rows(
        jnp.broadcast_to(jnp.asarray(row), (N, V)), pol))
    counts = np.bincount(toks, minlength=V)
    assert counts[[i for i in range(V) if i not in keep]].sum() == 0
    for v in keep:
        sd = np.sqrt(N * p_ref[v] * (1 - p_ref[v]))
        assert abs(counts[v] - N * p_ref[v]) <= 4 * sd + 1, (v, counts)


# ---------------------------------------------------------------------------
# engine-level: one trace per policy mix, greedy rows unperturbed
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    from repro.configs import get_smoke_config
    from repro.models import api
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, api.init_params(cfg, jax.random.key(0))


def _mk_reqs(n=4, max_new=6, sample_odd=False):
    from repro.runtime.serving import Request
    reqs = [Request(rid=i, prompt=[2 + i, 9, 4, 1 + i, 7], max_new=max_new)
            for i in range(n)]
    if sample_odd:
        for r in reqs[1::2]:
            r.params = SamplingParams(temperature=0.8, top_k=12,
                                      seed=31 + r.rid)
    return reqs


def test_mixed_policy_batch_compiles_one_step_trace(qwen):
    """The ISSUE 9 acceptance criterion: a mixed greedy+sampled batch
    runs through EXACTLY one decode trace (policies are operands, not
    constants), and the greedy rows emit the same tokens as an all-greedy
    engine — sampled neighbors never perturb them."""
    from repro.runtime.serving import PagedServingEngine
    cfg, params = qwen
    base = _mk_reqs()
    eng0 = PagedServingEngine(cfg, params, slots=4, max_len=32,
                              page_size=8, attn_impl="gather")
    eng0.run_to_completion(base)
    assert eng0.metrics()["sampling.step_traces"] == 1.0

    mixed = _mk_reqs(sample_odd=True)
    eng = PagedServingEngine(cfg, params, slots=4, max_len=32,
                             page_size=8, attn_impl="gather")
    eng.run_to_completion(mixed)
    m = eng.metrics()
    assert m["sampling.step_traces"] == 1.0          # no retrace for the mix
    assert m["sampling.greedy_requests"] == 2.0
    assert m["sampling.sampled_requests"] == 2.0
    assert m["sampling.greedy_tokens"] == 12.0
    assert m["sampling.sampled_tokens"] == 12.0
    for b, r in zip(base, mixed):
        if r.params is None:
            assert r.generated == b.generated, r.rid
    # near-uniform smoke logits: sampling at temp 0.8 diverges somewhere
    assert any(r.generated != b.generated
               for b, r in zip(base, mixed) if r.params is not None)


def test_dense_mixed_policy_trace_count_is_mix_invariant(qwen):
    """The dense engine jits the sampler per logit SHAPE (prefill (1,V),
    batched decode (slots,V)) — a greedy/sampled mix must not add
    traces beyond what the all-greedy engine compiles."""
    from repro.runtime.serving import DenseServingEngine
    cfg, params = qwen
    eng0 = DenseServingEngine(cfg, params, slots=2, max_len=16)
    eng0.run_to_completion(_mk_reqs(max_new=4))
    baseline = eng0.metrics()["sampling.step_traces"]
    assert baseline > 0

    eng = DenseServingEngine(cfg, params, slots=2, max_len=16)
    eng.run_to_completion(_mk_reqs(max_new=4, sample_odd=True))
    m = eng.metrics()
    assert m["sampling.step_traces"] == baseline
    assert m["sampling.sampled_requests"] == 2.0


def test_scheduler_validates_params_at_enqueue(qwen):
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import PagedServingEngine, Request
    cfg, params = qwen
    eng = PagedServingEngine(cfg, params, slots=1, max_len=16, page_size=8)
    sched = Scheduler(eng)
    bad = Request(rid=0, prompt=[1, 2], max_new=2,
                  params=SamplingParams(temperature=-1.0))
    with pytest.raises(ValueError, match="temperature"):
        sched.add(bad)


# ---------------------------------------------------------------------------
# loud failure modes (satellite: stale fallback texts)
# ---------------------------------------------------------------------------


def test_factory_dense_fallback_raises_on_drafter():
    from repro.configs import get_smoke_config
    from repro.models import api
    from repro.runtime.drafter import NgramDrafter
    from repro.runtime.serving import ServingEngine
    cfg = get_smoke_config("seamless-m4t-large-v2")     # enc-dec: dense
    params = api.param_shapes(cfg)      # engine init never touches params
    with pytest.raises(ValueError, match="verify step"):
        ServingEngine(cfg, params, slots=2, max_len=32,
                      drafter=NgramDrafter())


def test_draft_model_drafter_rejects_non_attention_stacks():
    from repro.configs import get_smoke_config
    from repro.runtime.drafter import DraftModelDrafter
    cfg = get_smoke_config("mamba2-2.7b")
    with pytest.raises(ValueError, match="n-gram"):
        DraftModelDrafter(cfg, None)


# ---------------------------------------------------------------------------
# DraftModelDrafter: paged-cache sync invariants
# ---------------------------------------------------------------------------


def test_draft_model_drafter_rollback_and_replay(qwen):
    from repro.runtime.drafter import DraftModelDrafter
    cfg, params = qwen
    dr = DraftModelDrafter(cfg, params, page_size=4, num_pages=16,
                           max_len=64)
    ctx = [5, 3, 8, 1, 2, 9]
    d1 = dr.propose(0, ctx, 3)
    assert len(d1) == 3
    # the verify step rejected draft 1: the new context keeps draft 0 and
    # appends a diverging residual token. The resulting sub-page
    # truncate_to used to trip the allocator's token-count assertion when
    # _ensure skipped extend_to for already-covered growth (ISSUE 9
    # regression).
    ctx2 = ctx + [d1[0], (d1[1] + 1) % cfg.vocab]
    d2 = dr.propose(0, ctx2, 3)
    assert len(d2) == 3
    assert dr.alloc.tokens(0) == len(ctx2) + len(d2) - 1
    dr.alloc.check_no_aliasing()
    # replaying the same context truncates the cached speculation again
    # and must reproduce the proposal exactly (greedy drafting over
    # identical cached KV + identical block shapes is deterministic)
    assert dr.propose(0, list(ctx2), 3) == d2
    dr.drop(0)
    assert dr.alloc.allocated_pages == 0


def test_draft_model_drafter_degrades_on_pool_exhaustion(qwen):
    from repro.runtime.drafter import DraftModelDrafter
    cfg, params = qwen
    dr = DraftModelDrafter(cfg, params, page_size=4, num_pages=1,
                           max_len=64)
    # 6 context tokens need 2 pages; the pool has 1 and nothing to evict:
    # degrade to no-draft (the engine then runs a plain decode row)
    assert dr.propose(0, [5, 3, 8, 1, 2, 9], 2) == []
    assert dr.stats()["draft_pool_rejects"] == 1.0
    assert dr.alloc.allocated_pages == 0


# ---------------------------------------------------------------------------
# cross-engine / preemption determinism (slow: several engine builds)
# ---------------------------------------------------------------------------


def _sampled_reqs(n=3, max_new=8):
    from repro.runtime.serving import Request
    return [Request(rid=i, prompt=[3 + i, 1, 4, 1, 5 + i], max_new=max_new,
                    params=SamplingParams(temperature=0.9, top_k=8,
                                          top_p=0.9, seed=900 + i))
            for i in range(n)]


@pytest.mark.slow
def test_sampled_identical_across_impls_and_engines(qwen):
    """Same (seed, rid, idx) -> same token, independent of the attention
    impl, the engine (paged vs dense) and slot assignment under
    continuous batching (3 requests on 2 slots)."""
    from repro.runtime.serving import DenseServingEngine, PagedServingEngine
    cfg, params = qwen

    def run_paged(impl):
        eng = PagedServingEngine(cfg, params, slots=2, max_len=32,
                                 page_size=8, attn_impl=impl)
        reqs = _sampled_reqs()
        eng.run_to_completion(reqs)
        return [r.generated for r in reqs]

    gather, kernel = run_paged("gather"), run_paged("kernel")
    assert gather == kernel
    dense = DenseServingEngine(cfg, params, slots=2, max_len=32)
    reqs = _sampled_reqs()
    dense.run_to_completion(reqs)
    assert [r.generated for r in reqs] == gather
    # the three seeds really produced three distinct streams
    assert len({tuple(t) for t in gather}) == 3


@pytest.mark.slow
def test_sampled_preemption_resume_replays_identical(qwen):
    """A preempted sampled request resumes by re-prefill and must replay
    the IDENTICAL token stream: the draw for generated token idx is a
    pure function of (seed, rid, idx), not of batch/preemption history."""
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import PagedServingEngine
    cfg, params = qwen

    def run(**kw):
        eng = PagedServingEngine(cfg, params, slots=2, max_len=32,
                                 page_size=4, attn_impl="gather", **kw)
        sched = Scheduler(eng)
        reqs = _sampled_reqs(n=2, max_new=8)
        for r in reqs:
            sched.add(r)
        sched.drain(max_steps=400)
        return [r.generated for r in reqs], sched

    want, _ = run()
    got, sched = run(num_pages=5)      # too small for both: preempts
    assert sched.preempted >= 1
    assert got == want


# ---------------------------------------------------------------------------
# rejection-sampled speculation preserves the sampling distribution
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_rejection_sampled_spec_matches_nonspec_distribution(qwen):
    """The distribution contract behind lifting the spec_k => greedy
    restriction: tokens emitted through the verify step's accept/residual
    rule are marginally distributed EXACTLY like non-speculative samples.
    Two independent cohorts (disjoint per-request seeds) of 200 requests
    sample token positions 1-2 without spec_k and with spec_k=4 fed by
    the SELF-draft model drafter (sampled continuations rarely repeat, so
    n-gram lookup would propose nothing — the draft model always does,
    and self-drafting maximizes the accept path's coverage); a two-sample
    chi-square over the vocab bins must not reject at p ~= 0.001
    (deterministic given the fixed seeds)."""
    from repro.runtime.drafter import DraftModelDrafter
    from repro.runtime.serving import PagedServingEngine, Request
    cfg, params = qwen
    N = 200
    prompt = [3, 1, 4, 1, 3, 1, 4, 1, 3]

    def run(spec_k, seed_base, drafter=None):
        eng = PagedServingEngine(cfg, params, slots=8, max_len=32,
                                 page_size=8, attn_impl="gather",
                                 spec_k=spec_k, drafter=drafter)
        reqs = [Request(rid=i, prompt=list(prompt), max_new=3,
                        params=SamplingParams(temperature=0.6, top_k=8,
                                              seed=seed_base + i))
                for i in range(N)]
        eng.run_to_completion(reqs, max_steps=8000)
        assert all(r.done for r in reqs)
        return [r.generated for r in reqs], eng

    plain, _ = run(0, 10_000)
    spec, eng = run(4, 20_000, DraftModelDrafter(cfg, params, max_len=64))
    ss = eng.spec_stats()
    assert ss["spec_drafted"] > 0 and ss["spec_accepted"] > 0
    for pos in (1, 2):
        a = np.bincount([t[pos] for t in plain], minlength=cfg.vocab)
        b = np.bincount([t[pos] for t in spec], minlength=cfg.vocab)
        mask = (a + b) > 0
        stat = (((a - b) ** 2)[mask] / (a + b)[mask].astype(float)).sum()
        df = int(mask.sum()) - 1
        # Wilson-Hilferty chi-square critical value at z = 3.09 (p ~ 1e-3)
        crit = df * (1 - 2 / (9 * df) + 3.09 * np.sqrt(2 / (9 * df))) ** 3
        assert stat < crit, (pos, stat, crit, df)
