"""AsyncCheckpointer failure semantics: a background save that dies must
re-raise on the NEXT save() or wait() — never vanish. The host-tier swap
path (runtime/host_tier.py with persist_dir=) persists swap records
through this class, so a silent failure there would mean silently
non-durable swap state."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import AsyncCheckpointer, restore
from repro.runtime.host_tier import HostTier, SwapRecord


def _state():
    return {"w": jnp.arange(6.0).reshape(2, 3)}


def test_async_save_round_trips(tmp_path):
    ck = AsyncCheckpointer()
    path = str(tmp_path / "step_1")
    ck.save(path, _state(), extra={"step": 1})
    ck.wait()
    assert ck.completed_saves == 1 and ck.failed_saves == 0
    got, extra = restore(path, _state())
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(_state()["w"]))
    assert extra == {"step": 1}


def test_failed_background_save_reraises_on_next_save(tmp_path):
    ck = AsyncCheckpointer()
    # an unwritable destination: the background thread's os.makedirs dies
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("file where a directory must go")
    bad = str(blocker / "ckpt")
    ck.save(bad, _state())
    with pytest.raises(OSError):
        ck.save(str(tmp_path / "step_2"), _state())     # re-raised HERE
    assert ck.failed_saves == 1
    # the error was consumed by raising: the checkpointer is usable again
    ck.wait()
    ck.save(str(tmp_path / "step_3"), _state())
    ck.wait()
    assert ck.completed_saves == 1
    assert os.path.isdir(tmp_path / "step_3")


def test_failed_background_save_reraises_on_wait(tmp_path):
    ck = AsyncCheckpointer()
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("")
    ck.save(str(blocker / "ckpt"), _state())
    with pytest.raises(OSError):
        ck.wait()
    ck.wait()                                           # consumed: clean now
    assert ck.failed_saves == 1 and ck.last_error is None


def test_host_tier_persist_failure_is_loud(tmp_path):
    """HostTier(persist_dir=...) rides AsyncCheckpointer: a failing persist
    surfaces on the tier's next drain() (the once-per-decode-tick hook),
    not never."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("")
    tier = HostTier(persist_dir=str(blocker / "swaps"))
    h = tier.store.put({"k": jnp.zeros((2, 4), jnp.int8)})
    tier.record_swap(SwapRecord(rid=1, pos=4, full=h, full_pages=1))
    tier._ckpt._thread.join()                           # let the save die
    with pytest.raises(OSError):
        tier.drain()
    assert tier._ckpt.failed_saves == 1


def test_host_tier_persist_writes_restorable_swaps(tmp_path):
    tier = HostTier(persist_dir=str(tmp_path))
    blob = {"k": jnp.arange(8, dtype=jnp.int8).reshape(2, 4)}
    h = tier.store.put(blob)
    tier.record_swap(SwapRecord(rid=3, pos=9, full=h, full_pages=2))
    tier._ckpt.wait()
    got, extra = restore(str(tmp_path / "swap_3"),
                         {str(h): {"k": jnp.zeros((2, 4), jnp.int8)}})
    np.testing.assert_array_equal(np.asarray(got[str(h)]["k"]),
                                  np.asarray(blob["k"]))
    assert extra["rid"] == 3 and extra["pos"] == 9
