"""Tensor-parallel serving: sharded == single-shard exact greedy
equivalence, mesh construction, divisibility fallbacks, replica router.

Multi-device cases need a forced multi-device CPU backend
(``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set BEFORE jax
initializes — the CI matrix has a leg for it) and skip gracefully on a
single-device run. The 1x1-mesh case always runs: it exercises the whole
shard_map path — specs, manual rules, boundary placement — on any
backend, so a plain local `pytest` still covers the machinery.

Equivalence is token-for-token under greedy sampling with float32 params:
the TP psum reorders the out-projection accumulation, which fp32 absorbs
below argmax-flip threshold on the smoke configs; the single-shard
baseline and every sharded engine must emit IDENTICAL token streams.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.parallel.sharding import Rules
from repro.parallel.tp import tp_plan
from repro.runtime.router import ReplicaRouter, make_replicas
from repro.runtime.serving import PagedServingEngine, Request

N_DEV = len(jax.devices())

needs2 = pytest.mark.skipif(
    N_DEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
needs4 = pytest.mark.skipif(
    N_DEV < 4, reason="needs >=4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def _cfg(arch="qwen2.5-3b"):
    # fp32 params: greedy equivalence must survive the psum's reordered
    # accumulation without argmax flips
    return dataclasses.replace(get_smoke_config(arch), dtype="float32")


def _params(cfg):
    return api.init_params(cfg, jax.random.key(0))


def _reqs(n=4, max_new=6):
    return [Request(rid=i, prompt=[1 + i, 7, 3 + i, 9, 2], max_new=max_new)
            for i in range(n)]


def _tokens(cfg, params, *, mesh, n=4, max_new=6, **kw):
    eng = PagedServingEngine(cfg, params, slots=3, max_len=64, page_size=8,
                             mesh=mesh, **kw)
    reqs = _reqs(n, max_new)
    eng.run_to_completion(reqs)
    assert all(r.done for r in reqs)
    return [r.generated for r in reqs], eng


# ---------------------------------------------------------------------------
# mesh construction (satellite: make_host_mesh hardening)
# ---------------------------------------------------------------------------


def test_make_host_mesh_rejects_bad_fold():
    with pytest.raises(ValueError) as e:
        make_host_mesh(model=N_DEV + 1)
    msg = str(e.value)
    assert str(N_DEV) in msg and str(N_DEV + 1) in msg  # names n AND model
    with pytest.raises(ValueError):
        make_host_mesh(model=0)
    with pytest.raises(ValueError):
        make_host_mesh(model=3, devices=jax.devices()[:1])


def test_make_host_mesh_devices_override():
    mesh = make_host_mesh(model=1, devices=jax.devices()[:1])
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    assert list(np.ravel(mesh.devices)) == jax.devices()[:1]


@needs4
def test_make_host_mesh_folds_data_axis():
    mesh = make_host_mesh(model=2)
    assert dict(mesh.shape) == {"data": N_DEV // 2, "model": 2}


# ---------------------------------------------------------------------------
# divisibility fallback (satellite: loud replication, serving inherits it)
# ---------------------------------------------------------------------------


def test_rules_divisibility_fallback_no_warning_when_divisible():
    # a 1-wide model axis divides everything: the divisible path must stay
    # silent (the loud path needs >= 2 devices; covered below)
    mesh = make_host_mesh(model=1, devices=jax.devices()[:1])
    rules = Rules(mesh)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        spec = rules.spec((4, 8), "heads,ffn")
    assert spec[0] == "model"                       # heads sharded (1-wide)


@needs2
def test_rules_divisibility_fallback_warns_and_replicates():
    mesh = make_host_mesh(model=2, devices=jax.devices()[:2])
    rules = Rules(mesh)
    with pytest.warns(UserWarning, match="'heads'"):
        spec = rules.spec((5, 6), "heads,ffn")
    assert spec == jax.sharding.PartitionSpec(None, "model")
    with warnings.catch_warnings():                 # once per (instance, axis)
        warnings.simplefilter("error")
        rules.spec((5, 6), "heads,ffn")


@needs2
def test_tp_plan_gqa_coupling_and_moe():
    cfg = _cfg()                                    # heads=4 kv=2 d_ff=128
    mesh = make_host_mesh(model=2, devices=jax.devices()[:2])
    plan = tp_plan(cfg, mesh)
    assert "kv_heads" in plan.sharded_axes and "ffn" in plan.sharded_axes
    assert plan.rules.contract_axes == frozenset({"heads", "ffn"})
    assert tp_plan(cfg, None) is None
    with pytest.raises(ValueError, match="model"):
        tp_plan(cfg, jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]).reshape(1), ("data",)))


@needs4
def test_serving_inherits_fallback_instead_of_crashing():
    """kv_heads=2 on model=4: attention replicates (with a loud warning)
    but the engine still serves, and still matches the baseline."""
    cfg, params = _cfg(), None
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None)
    with pytest.warns(UserWarning, match="kv_heads"):
        mesh = make_host_mesh(model=4, devices=jax.devices()[:4])
        toks, eng = _tokens(cfg, params, mesh=mesh)
    assert toks == base
    assert "kv_heads" not in eng.tp.sharded_axes    # attention fell back
    assert "ffn" in eng.tp.sharded_axes             # 128 % 4 == 0: ffn kept


# ---------------------------------------------------------------------------
# sharded == single-shard greedy equivalence (the tentpole contract)
# ---------------------------------------------------------------------------


def test_tp1_mesh_matches_plain_engine():
    """A 1x1 mesh runs the FULL shard_map machinery on one device — the
    always-on canary for the TP path (no multi-device backend needed)."""
    cfg, params = _cfg(), None
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None)
    mesh = make_host_mesh(model=1, devices=jax.devices()[:1])
    toks, eng = _tokens(cfg, params, mesh=mesh)
    assert toks == base
    assert eng.shard_stats()["model_shards"] == 1.0


@needs2
@pytest.mark.parametrize("attn_impl", ["kernel", "gather"])
def test_tp2_exact_equivalence(attn_impl):
    cfg = _cfg()
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None, attn_impl=attn_impl)
    mesh = make_host_mesh(model=2, devices=jax.devices()[:2])
    toks, eng = _tokens(cfg, params, mesh=mesh, attn_impl=attn_impl)
    assert toks == base
    st = eng.shard_stats()
    assert st["model_shards"] == 2.0
    assert st["peak_pages_per_shard"] == float(eng.alloc.peak_pages)


@needs4
@pytest.mark.slow
@pytest.mark.parametrize("attn_impl", ["kernel", "gather"])
def test_tp4_exact_equivalence(attn_impl):
    cfg = _cfg()
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None, attn_impl=attn_impl)
    with pytest.warns(UserWarning):                 # kv_heads=2 falls back
        mesh = make_host_mesh(model=4)
        toks, _ = _tokens(cfg, params, mesh=mesh, attn_impl=attn_impl)
    assert toks == base


@needs2
def test_tp2_with_prefix_cache():
    cfg = _cfg()
    params = _params(cfg)
    sys_p = [9, 9, 9, 9, 8, 8, 8, 8, 7, 7]          # shared page + partial
    reqs = lambda: [Request(rid=i, prompt=sys_p + [i + 1, i + 2],  # noqa: E731
                            max_new=5) for i in range(4)]
    base_eng = PagedServingEngine(cfg, params, slots=2, max_len=64,
                                  page_size=8)
    b = reqs()
    base_eng.run_to_completion(b)
    mesh = make_host_mesh(model=2, devices=jax.devices()[:2])
    eng = PagedServingEngine(cfg, params, slots=2, max_len=64, page_size=8,
                             prefix_cache=True, mesh=mesh)
    r = reqs()
    eng.run_to_completion(r)
    assert [x.generated for x in r] == [x.generated for x in b]
    assert eng.prefix_stats()["prefill_tokens_saved"] > 0  # sharing happened


@needs2
@pytest.mark.slow
def test_tp2_with_speculative_decode():
    cfg = _cfg()
    params = _params(cfg)
    # repetitive prompts so the n-gram drafter actually lands accepts
    reqs = lambda: [Request(rid=i, prompt=[5, 6, 5, 6, 5, 6, 5],  # noqa: E731
                            max_new=8) for i in range(3)]
    base_eng = PagedServingEngine(cfg, params, slots=3, max_len=64,
                                  page_size=8)
    b = reqs()
    base_eng.run_to_completion(b)
    mesh = make_host_mesh(model=2, devices=jax.devices()[:2])
    eng = PagedServingEngine(cfg, params, slots=3, max_len=64, page_size=8,
                             spec_k=3, mesh=mesh)
    r = reqs()
    eng.run_to_completion(r)
    assert [x.generated for x in r] == [x.generated for x in b]


@needs2
@pytest.mark.slow
def test_tp2_preemption_resume():
    """A page pool too small for all requests forces preemption; the
    preempted request resumes by re-prefill on SHARDED pools and must
    still match the unsharded engine run under the same pressure."""
    cfg = _cfg()
    params = _params(cfg)

    def run(mesh):
        eng = PagedServingEngine(cfg, params, slots=3, max_len=64,
                                 page_size=8, num_pages=5, mesh=mesh)
        reqs = [Request(rid=i, prompt=[1 + i, 7, 3 + i, 9, 2, 4, 6],
                        max_new=10) for i in range(3)]
        eng.run_to_completion(reqs)
        return reqs

    base = run(None)
    shard = run(make_host_mesh(model=2, devices=jax.devices()[:2]))
    assert sum(r.preemptions for r in base) > 0     # pressure was real
    assert [r.generated for r in shard] == [r.generated for r in base]
    assert [r.preemptions for r in shard] == [r.preemptions for r in base]


@needs2
@pytest.mark.slow
@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "mamba2-2.7b"])
def test_tp2_hybrid_stacks(arch):
    """Windowed + recurrent stacks: mixer state replicates, whatever can
    shard shards (rgemma smoke kv_heads=1 -> attention falls back), and
    outputs still match token-for-token."""
    cfg = _cfg(arch)
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None)
    mesh = make_host_mesh(model=2, devices=jax.devices()[:2])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)   # kv fallback ok
        toks, _ = _tokens(cfg, params, mesh=mesh)
    assert toks == base


# ---------------------------------------------------------------------------
# sampled equivalence: decode policies ride OUTSIDE shard_map, so the
# per-request PRNG sees identical logits and keys at every shard count
# ---------------------------------------------------------------------------


def _sampled_tokens(cfg, params, *, mesh, n=4, max_new=6):
    from repro.runtime.sampling import SamplingParams
    eng = PagedServingEngine(cfg, params, slots=3, max_len=64, page_size=8,
                             mesh=mesh)
    reqs = _reqs(n, max_new)
    for i, r in enumerate(reqs):
        r.params = SamplingParams(temperature=0.9, top_k=6, top_p=0.9,
                                  seed=100 + i)
    eng.run_to_completion(reqs)
    assert all(r.done for r in reqs)
    return [r.generated for r in reqs]


def test_tp1_mesh_sampled_matches_plain():
    cfg = _cfg()
    params = _params(cfg)
    base = _sampled_tokens(cfg, params, mesh=None)
    mesh = make_host_mesh(model=1, devices=jax.devices()[:1])
    assert _sampled_tokens(cfg, params, mesh=mesh) == base
    # distinct per-request seeds really produced distinct streams
    assert len({tuple(t) for t in base}) == len(base)


@needs2
def test_tp2_sampled_equivalence():
    cfg = _cfg()
    params = _params(cfg)
    base = _sampled_tokens(cfg, params, mesh=None)
    mesh = make_host_mesh(model=2, devices=jax.devices()[:2])
    assert _sampled_tokens(cfg, params, mesh=mesh) == base


@needs4
@pytest.mark.slow
def test_tp4_sampled_equivalence():
    cfg = _cfg()
    params = _params(cfg)
    base = _sampled_tokens(cfg, params, mesh=None)
    with pytest.warns(UserWarning):                 # kv_heads=2 falls back
        mesh = make_host_mesh(model=4)
        toks = _sampled_tokens(cfg, params, mesh=mesh)
    assert toks == base


# ---------------------------------------------------------------------------
# replica router
# ---------------------------------------------------------------------------


def test_router_single_replica_matches_engine():
    cfg = _cfg()
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None, n=5)
    rr = make_replicas(cfg, params, replicas=1, slots=3, max_len=64,
                       page_size=8)
    reqs = _reqs(5)
    rr.run_to_completion(reqs)
    assert [r.generated for r in reqs] == base
    assert rr.stats()["routed"] == [5]


def test_router_validates():
    cfg = _cfg()
    params = _params(cfg)
    with pytest.raises(ValueError, match="device"):
        make_replicas(cfg, params, replicas=N_DEV + 1)
    with pytest.raises(ValueError, match="policy"):
        ReplicaRouter([object()], policy="round_robin")
    with pytest.raises(ValueError):
        ReplicaRouter([])


@needs2
@pytest.mark.parametrize("policy", ["hash", "least_loaded"])
def test_router_replicas_match_baseline(policy):
    cfg = _cfg()
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None, n=6)
    rr = make_replicas(cfg, params, replicas=2, slots=3, max_len=64,
                       page_size=8, policy=policy)
    reqs = _reqs(6)
    rr.run_to_completion(reqs)
    assert [r.generated for r in reqs] == base
    st = rr.stats()
    assert sum(st["routed"]) == 6 and min(st["routed"]) > 0
    assert len(st["peak_pages_per_shard"]) == 2


@needs4
@pytest.mark.slow
def test_router_tp_replicas_compose():
    """2 replicas x 2 shards on 4 devices: DP and TP together."""
    cfg = _cfg()
    params = _params(cfg)
    base, _ = _tokens(cfg, params, mesh=None, n=6)
    rr = make_replicas(cfg, params, replicas=2, model=2, slots=3,
                       max_len=64, page_size=8)
    reqs = _reqs(6)
    rr.run_to_completion(reqs)
    assert [r.generated for r in reqs] == base
    assert rr.stats()["model_shards"] == [2.0, 2.0]
