"""Hypothesis property test for the PDMA Arena allocator: random
alloc/free interleavings always preserve bank-word alignment, block
disjointness, and the capacity bound — the host-model mirror of what
tests/test_kv_cache.py pins for the serving page pool."""
from hypothesis import given, settings, strategies as st

from repro.core.accel import VOLTRA
from repro.core.pdma import Arena, ArenaError

# (alloc?, size) pairs; sizes span "many small" through "a third of the
# arena", so some sequences exhaust capacity and hit the ArenaError path.
_ops = st.lists(
    st.tuples(st.booleans(), st.integers(1, VOLTRA.mem_bytes // 3)),
    min_size=1, max_size=40)


@settings(max_examples=30, deadline=None)
@given(_ops)
def test_arena_interleavings_keep_invariants(ops):
    a = Arena()
    live = {}
    n = 0
    for is_alloc, size in ops:
        if is_alloc or not live:
            name = f"b{n}"
            n += 1
            used_before = a.used
            blocks_before = len(a.blocks)
            try:
                blk = a.alloc(name, size)
            except ArenaError:
                # rejected: state untouched, and the request really was
                # bigger than the whole arena could ever hold contiguously
                assert a.used == used_before
                assert len(a.blocks) == blocks_before
                continue
            live[name] = blk
            # bank-word alignment of both placement and rounded size
            assert blk.offset % a.align == 0
            assert blk.size % a.align == 0
            assert blk.size >= size
            assert blk.offset + blk.size <= a.capacity
        else:
            # free a deterministically-chosen live block (drawn data picks
            # the index, so hypothesis can shrink failing interleavings)
            name = sorted(live)[size % len(live)]
            a.free(name)
            del live[name]
        # global invariants after EVERY op
        assert not a.overlaps()
        assert a.used <= a.capacity
        assert a.used == sum(b.size for b in live.values())
    for name in sorted(live):
        a.free(name)
    assert a.used == 0 and not a.blocks


@settings(max_examples=20, deadline=None)
@given(st.integers(VOLTRA.mem_bytes // 64, VOLTRA.mem_bytes),
       st.integers(0, 1 << 30))
def test_arena_free_then_realloc_reuses_space(size, salt):
    """free() really returns space: fill-free-fill of the same size never
    hits ArenaError (the dynamic (re)partitioning PDMA promises)."""
    a = Arena()
    names = []
    i = 0
    while True:
        try:
            a.alloc(f"x{i}", size)
        except ArenaError:
            break
        names.append(f"x{i}")
        i += 1
    assert names, "a <= capacity block must place in an empty arena"
    victim = names[salt % len(names)]
    a.free(victim)
    a.alloc("again", size)          # must fit where the victim sat
    assert not a.overlaps()
    assert a.used <= a.capacity
