"""Two-tier KV hierarchy (runtime/host_tier.py + the allocator's host
class): bookkeeping units, a hypothesis property test over random
tier-op interleavings with a real byte-level pool mimic, and slow
engine-level equivalence tests — a pool capped far below the working set
must emit the unconstrained engine's exact greedy tokens with ZERO
re-prefilled tokens (swap-in resume), across plain, prefix-cached and
hybrid (recurrent-state) stacks and both attention impls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import api
from repro.runtime.host_tier import (CopyStream, HostPageStore, HostTier,
                                     SwapRecord)
from repro.runtime.kv_cache import PageAllocator
from repro.runtime.scheduler import Scheduler
from repro.runtime.serving import PagedServingEngine, Request

# ---------------------------------------------------------------------------
# allocator host-class units
# ---------------------------------------------------------------------------


def test_demote_frees_pages_promote_rebuilds():
    a = PageAllocator(num_pages=4, page_size=4)
    t = a.allocate(1, 7)
    assert t is not None and len(t) == 2
    old = a.demote(1)
    assert old == t
    assert a.host_resident(1) and not a.live_requests
    assert a.free_pages == 4 and a.host_tokens(1) == 7
    a.check()
    new = a.promote(1)
    assert new is not None and len(new) == 2
    assert not a.host_resident(1) and a.tokens(1) == 7
    a.check()
    a.free_request(1)
    assert a.allocated_pages == 0


def test_demote_preserves_window_base():
    a = PageAllocator(num_pages=4, page_size=4)
    a.allocate(1, 13, base_blocks=2)        # blocks 0,1 never allocated
    a.demote(1)
    assert a.host_base_blocks(1) == 2
    assert a.host_pages_needed(1) == a.pages_for(13) - 2
    a.check()
    t = a.promote(1)
    assert len(t) == a.pages_for(13) - 2
    assert a.base_blocks(1) == 2
    a.check()


def test_promote_refuses_when_pool_dry_state_unchanged():
    a = PageAllocator(num_pages=2, page_size=4)
    a.allocate(1, 8)
    a.demote(1)
    a.allocate(2, 8)                         # takes the whole pool back
    assert a.promote(1) is None
    assert a.host_resident(1)                # unchanged: still promotable
    a.check()
    a.free_request(2)
    assert a.promote(1) is not None
    a.check()


def test_demote_shared_page_survives_other_references():
    a = PageAllocator(num_pages=4, page_size=4)
    t1 = a.allocate(1, 4)
    a.allocate_shared(2, 8, t1)              # rid 2 shares rid 1's page
    a.demote(1)
    assert a.ref(t1[0]) == 1                 # rid 2's claim survives
    a.check()
    a.promote(1)                             # fully private rebuild
    assert a.ref(t1[0]) == 1
    a.check()


def test_alloc_pinned_page_only_reference_is_the_pin():
    a = PageAllocator(num_pages=2, page_size=4)
    p = a.alloc_pinned_page()
    assert p is not None and a.is_pinned(p) and a.ref(p) == 1
    a.check()
    assert a.cache_unpin(p)                  # pin was the only ref -> free
    assert a.allocated_pages == 0
    a.check()


# ---------------------------------------------------------------------------
# host store / copy stream units
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float32])
def test_store_round_trips_bitwise(dtype):
    store = HostPageStore()
    blob = {"k": jnp.arange(-8, 8, dtype=dtype).reshape(4, 4)}
    h = store.put(blob)
    assert h in store and len(store) == 1
    assert store.drain() == 1 and store.drain() == 0
    got = store.get(h)
    assert got["k"].dtype == np.dtype(dtype)
    np.testing.assert_array_equal(got["k"], np.asarray(blob["k"]))
    store.pop(h)
    assert store.bytes_stored == 0 and h not in store


def test_stream_prefetch_hit_vs_demand_fetch():
    store = HostPageStore()
    stream = CopyStream(store)
    h1 = store.put({"k": jnp.ones((2, 2))})
    h2 = store.put({"k": jnp.zeros((2, 2))})
    stream.prefetch(h1)
    stream.prefetch(h1)                      # idempotent while in flight
    assert stream.prefetch_starts == 1
    np.testing.assert_array_equal(np.asarray(stream.take(h1)["k"]), 1.0)
    np.testing.assert_array_equal(np.asarray(stream.take(h2)["k"]), 0.0)
    assert stream.prefetch_hits == 1 and stream.demand_fetches == 1
    stream.prefetch(999)                     # absent handle: no-op
    assert stream.prefetch_starts == 1


def test_tier_swap_record_lifecycle_and_cap():
    tier = HostTier(max_bytes=64)
    h = tier.store.put({"k": jnp.zeros(8, jnp.int8)})       # 8 bytes
    tier.record_swap(SwapRecord(rid=5, pos=12, full=h, full_pages=2))
    assert tier.has_swap(5) and tier.swap_outs == 1
    assert tier.can_accept(56) and not tier.can_accept(57)
    assert tier.refused_demotions == 1
    rec = tier.pop_swap(5)
    assert rec.pos == 12 and not tier.has_swap(5)
    assert tier.swap_ins == 1 and tier.reprefill_tokens_saved == 12
    assert tier.store.bytes_stored == 0


def test_tier_window_archive_cap_evicts_fifo():
    tier = HostTier(win_archive_pages=3)
    hs = [tier.store.put({"k": jnp.zeros((2, 4))}) for _ in range(3)]
    for i, h in enumerate(hs):
        tier.archive_window(rid=1, base_block=2 * i, n_pages=2, handle=h)
    # 6 pages archived against a 3-page cap: the two OLDEST entries drop
    assert tier.win_archived_pages == 2 and tier.win_archive_drops == 2
    assert hs[0] not in tier.store and hs[2] in tier.store


# ---------------------------------------------------------------------------
# property test: random tiering interleavings against a byte-level mimic
# ---------------------------------------------------------------------------

# (op 0..5, a, b): op selects allocate/extend/truncate/demote/promote/free;
# a/b select the rid / sizes modulo the live population, so hypothesis can
# shrink failing interleavings without invalid-op waste.
_tier_ops = st.lists(
    st.tuples(st.integers(0, 5), st.integers(0, 1 << 16),
              st.integers(0, 1 << 16)),
    min_size=1, max_size=60)

_P = 4          # page size
_N = 6          # usable pages — small, so ops collide and refuse often


def _val(rid: int, idx: int, dtype) -> np.ndarray:
    """Deterministic per-(request, token) cell value — any clobbered or
    aliased page row shows up as a value mismatch, bitwise."""
    if np.dtype(dtype) == np.int8:
        return np.int8((rid * 31 + idx * 7) % 251 - 125)
    return np.float32(rid * 100.0 + idx)


class _PoolMimic:
    """NumPy stand-in for the device pool + host store: demote gathers the
    table's page rows to a host copy, promote scatters them into the fresh
    table — the same contract the engine's jitted gather/scatter programs
    implement, minus the device."""

    def __init__(self, dtype):
        self.dtype = dtype
        self.pool = np.zeros((_N + 1, _P), dtype)      # row 0 = scratch
        self.host: dict = {}                           # rid -> gathered pages

    def write(self, alloc: PageAllocator, rid: int, lo: int, hi: int):
        base = alloc.base_blocks(rid) * _P
        table = alloc.block_table(rid)
        for idx in range(max(lo, base), hi):
            self.pool[table[idx // _P - alloc.base_blocks(rid)],
                      idx % _P] = _val(rid, idx, self.dtype)

    def verify(self, alloc: PageAllocator, rid: int):
        base = alloc.base_blocks(rid) * _P
        table = alloc.block_table(rid)
        for idx in range(base, alloc.tokens(rid)):
            got = self.pool[table[idx // _P - alloc.base_blocks(rid)],
                            idx % _P]
            assert got == _val(rid, idx, self.dtype), \
                f"rid {rid} token {idx}: {got} (aliased/clobbered page)"

    def demote(self, alloc: PageAllocator, rid: int):
        pages = alloc.demote(rid)            # gather-then-free contract
        self.host[rid] = self.pool[pages].copy()

    def promote(self, alloc: PageAllocator, rid: int) -> bool:
        table = alloc.promote(rid)
        if table is None:
            return False
        self.pool[table] = self.host.pop(rid)
        return True


@settings(max_examples=30, deadline=None)
@given(ops=_tier_ops)
def test_tiering_interleavings_keep_invariants(ops):
    # both pool dtypes per drawn interleaving: int8 pins the bitwise
    # round-trip (quantized pools), float32 the plain one — a dtype loop
    # rather than parametrize because the conftest hypothesis stub
    # replaces @given tests with zero-arg skippers on bare checkouts
    for dtype in (np.int8, np.float32):
        _run_tiering_interleaving(dtype, ops)


def _run_tiering_interleaving(dtype, ops):
    alloc = PageAllocator(num_pages=_N, page_size=_P)
    mimic = _PoolMimic(dtype)
    live, hosted = [], []
    next_rid = 0
    for op, a, b in ops:
        if op == 0 or not (live or hosted):                 # allocate
            rid = next_rid
            next_rid += 1
            base = (a % 2) if b % 3 == 0 else 0
            tokens = base * _P + 1 + a % (2 * _P)
            if alloc.allocate(rid, tokens, base_blocks=base) is not None:
                live.append(rid)
                mimic.write(alloc, rid, 0, tokens)
        elif op == 1 and live:                              # extend
            rid = live[a % len(live)]
            t0 = alloc.tokens(rid)
            grown = alloc.extend_to(rid, t0 + 1 + b % _P)
            if grown is not None:
                mimic.write(alloc, rid, t0, alloc.tokens(rid))
        elif op == 2 and live:                              # truncate
            rid = live[a % len(live)]
            floor = alloc.base_blocks(rid) * _P + 1
            span = alloc.tokens(rid) - floor
            if span > 0:
                alloc.truncate_to(rid, floor + b % (span + 1))
        elif op == 3 and live:                              # demote
            rid = live.pop(a % len(live))
            mimic.demote(alloc, rid)
            hosted.append(rid)
        elif op == 4 and hosted:                            # promote
            rid = hosted[a % len(hosted)]
            if mimic.promote(alloc, rid):
                hosted.remove(rid)
                live.append(rid)
            else:
                assert alloc.host_resident(rid)             # unchanged
        elif op == 5 and live:                              # free
            alloc.free_request(live.pop(a % len(live)))
        # global invariants after EVERY op: pool bookkeeping consistent,
        # host class disjoint from live tables, and every live request's
        # bytes intact — a host-resident page aliased into a live table
        # would fail the value check the moment either side writes
        alloc.check()
        assert not set(live) & set(hosted)
        for rid in live:
            mimic.verify(alloc, rid)
    # promote-after-demote round-trips bitwise, even at the very end
    for rid in list(hosted):
        while not mimic.promote(alloc, rid):
            alloc.free_request(live.pop())                  # make room
        mimic.verify(alloc, rid)
        alloc.free_request(rid)
    for rid in live:
        alloc.free_request(rid)
    assert alloc.allocated_pages == 0
    alloc.check()


# ---------------------------------------------------------------------------
# engine-level equivalence (slow): capped pool + tier == unconstrained
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, api.init_params(cfg, jax.random.key(0))


def _drain(engine, reqs):
    sched = Scheduler(engine)
    for r in reqs:
        sched.add(r)
    sched.drain(max_steps=600)
    return [list(r.generated) for r in reqs]


def _mixed(cfg, n=3, max_new=8):
    # prompt + max_new <= 16 tokens = 4 pages: every request is feasible
    # in the capped engine's 4-page pool, but two live at once are not —
    # decode MUST preempt (and with the tier on, swap) mid-trace
    return [Request(rid=i,
                    prompt=[(7 * i + 3 * j) % cfg.vocab
                            for j in range(3 + 2 * i)],
                    max_new=max_new)
            for i in range(n)]


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["gather", "kernel"])
def test_tiered_engine_matches_unconstrained(qwen, impl):
    """Pool capped far below the working set: swap-out/swap-in resume must
    reproduce the unconstrained engine's tokens with ZERO extra prefill
    (the evict-only path would re-prefill prompt + generated)."""
    cfg, params = qwen
    base = PagedServingEngine(cfg, params, slots=2, max_len=32,
                              page_size=4, num_pages=32, attn_impl=impl)
    want = _drain(base, _mixed(cfg))
    base_prefilled = base.prefilled_tokens

    eng = PagedServingEngine(cfg, params, slots=2, max_len=32,
                             page_size=4, num_pages=4, attn_impl=impl,
                             host_tier=True)
    reqs = _mixed(cfg)
    got = _drain(eng, reqs)
    assert got == want
    assert eng.tier.swap_outs > 0 and eng.tier.swap_ins == eng.tier.swap_outs
    assert sum(r.preemptions for r in reqs) == eng.tier.swap_outs
    assert eng.prefilled_tokens == base_prefilled       # zero re-prefill
    assert eng.tier.reprefill_tokens_saved > 0
    assert eng.tier.store.bytes_stored == 0             # all swapped back
    assert eng.alloc.allocated_pages == 0
    eng.alloc.check()


@pytest.mark.slow
def test_tiered_prefix_cache_demotes_and_promotes(qwen):
    """Idle radix nodes demote to host under pressure; a later match on a
    host-resident node promotes it back (prefetched by the scheduler hook)
    instead of re-prefilling — prefill compute equals the unconstrained
    prefix-cached engine's."""
    cfg, params = qwen
    pre_a = [7, 7, 7, 7, 3, 3, 3, 3]
    pre_b = [9, 9, 9, 9, 5, 5, 5, 5]

    def mk():
        return [Request(rid=0, prompt=pre_a + [1], max_new=6),
                Request(rid=1, prompt=pre_b + [1], max_new=6),
                Request(rid=2, prompt=pre_a + [2], max_new=6)]

    base = PagedServingEngine(cfg, params, slots=1, max_len=32,
                              page_size=4, num_pages=32,
                              attn_impl="gather", prefix_cache=True)
    want = _drain(base, mk())
    base_prefilled = base.prefilled_tokens

    eng = PagedServingEngine(cfg, params, slots=1, max_len=32,
                             page_size=4, num_pages=5, attn_impl="gather",
                             prefix_cache=True, host_tier=True)
    got = _drain(eng, mk())
    assert got == want
    assert eng.tier.cache_demotions > 0
    assert eng.tier.cache_promotions > 0
    assert eng.tier.stream.prefetch_hits > 0            # streamer ran ahead
    assert eng.prefilled_tokens == base_prefilled
    assert eng.prefix.stats()["host_nodes"] == eng.tier.cache_demotions \
        - eng.tier.cache_promotions
    eng.alloc.check()


@pytest.mark.slow
def test_tiered_hybrid_swaps_recurrent_state(qwen):
    """Hybrid stack preemption: window pages AND recurrent state slots
    swap to host; resume restores both without re-prefill — closing PR 5's
    'recurrent state cannot swap' limitation."""
    del qwen                                            # hybrid pins its arch
    cfg = get_smoke_config("recurrentgemma-9b")
    params = api.init_params(cfg, jax.random.key(0))
    window = cfg.hybrid.window

    def mk():
        return [Request(rid=i,
                        prompt=[(5 * i + j) % cfg.vocab
                                for j in range(window // 2 + 5 * i)],
                        max_new=8)
                for i in range(3)]

    base = PagedServingEngine(cfg, params, slots=2, max_len=32,
                              page_size=4, num_pages=32, attn_impl="gather")
    want = _drain(base, mk())
    base_prefilled = base.prefilled_tokens

    eng = PagedServingEngine(cfg, params, slots=2, max_len=32,
                             page_size=4, num_pages=6, attn_impl="gather",
                             host_tier=True)
    got = _drain(eng, mk())
    assert got == want
    assert eng.tier.swap_outs > 0                       # state really swapped
    assert eng.prefilled_tokens == base_prefilled
    assert eng.alloc.allocated_pages == 0
    eng.alloc.check()
