"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles,
all in Pallas interpret mode (the CPU contract for the TPU kernels)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gemm_os import gemm_os, spatial_utilization

# interpret-mode model/kernel tests: minutes on a throttled CPU
pytestmark = pytest.mark.slow


def _rand(key, shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, -128, 128).astype(dtype)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# gemm_os
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("mkn", [(8, 8, 8), (100, 300, 200), (128, 128, 128),
                                 (1, 512, 96), (257, 129, 65)])
def test_gemm_os_matches_ref(dtype, mkn):
    M, K, N = mkn
    x = _rand(jax.random.key(0), (M, K), dtype)
    w = _rand(jax.random.key(1), (K, N), dtype)
    got = gemm_os(x, w, block=(64, 64, 64), interpret=True)
    want = ref.gemm_ref(x, w)
    if dtype == jnp.int8:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2 if dtype == jnp.bfloat16 else 2e-3,
            atol=2e-1 if dtype == jnp.bfloat16 else 2e-3)


@pytest.mark.parametrize("block", [(8, 8, 8), (32, 16, 64), (128, 128, 128)])
def test_gemm_os_block_sweep(block):
    x = _rand(jax.random.key(2), (96, 160), jnp.float32)
    w = _rand(jax.random.key(3), (160, 72), jnp.float32)
    got = gemm_os(x, w, block=block, interpret=True)
    np.testing.assert_allclose(got, ref.gemm_ref(x, w), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("scale", [1.0, 0.01, 0.0005])
def test_quant_epilogue_exact(scale):
    x = _rand(jax.random.key(4), (64, 256), jnp.int8)
    w = _rand(jax.random.key(5), (256, 96), jnp.int8)
    got = ops.quant_matmul(x, w, scale, block=(32, 32, 64))
    np.testing.assert_array_equal(got, ref.gemm_ref(x, w, quant_scale=scale))
    assert got.dtype == jnp.int8


def test_int8_accumulates_in_int32():
    # 512 * 127 * 127 overflows int16 by far; int32 must hold it exactly
    x = jnp.full((8, 512), 127, jnp.int8)
    w = jnp.full((512, 8), 127, jnp.int8)
    got = gemm_os(x, w, block=(8, 8, 128), interpret=True)
    assert int(got[0, 0]) == 512 * 127 * 127


def test_spatial_utilization_formula():
    assert spatial_utilization(128, 128, 128) == 1.0
    assert spatial_utilization(1, 128, 128) == pytest.approx(1 / 128)
    assert spatial_utilization(129, 128, 128) == pytest.approx(129 / 256)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [
    # (B, Sq, Sk, H, KV, D, bq, bk)
    (1, 64, 64, 4, 4, 32, 32, 32),      # MHA
    (2, 100, 100, 8, 2, 32, 32, 32),    # GQA, ragged seq
    (2, 37, 53, 6, 3, 16, 8, 16),       # cross-ish lengths
    (1, 1, 64, 8, 1, 32, 16, 16),       # decode: one q row
])
@pytest.mark.parametrize("causal", [True, False])
def test_mha_matches_ref(shape, causal):
    B, Sq, Sk, H, KV, D, bq, bk = shape
    if causal and Sq > Sk:
        pytest.skip("causal assumes Sq <= Sk alignment")
    q = _rand(jax.random.key(0), (B, Sq, H, D), jnp.float32)
    k = _rand(jax.random.key(1), (B, Sk, KV, D), jnp.float32)
    v = _rand(jax.random.key(2), (B, Sk, KV, D), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


def test_mha_kv_valid():
    q = _rand(jax.random.key(0), (2, 16, 4, 16), jnp.float32)
    k = _rand(jax.random.key(1), (2, 64, 2, 16), jnp.float32)
    v = _rand(jax.random.key(2), (2, 64, 2, 16), jnp.float32)
    got = ops.attention(q, k, v, causal=False, kv_valid=33, bq=8, bk=16)
    want = ref.mha_ref(q, k, v, causal=False, kv_valid=33)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)
    # and it must differ from attending to the whole cache
    full = ref.mha_ref(q, k, v, causal=False)
    assert not np.allclose(got, full, atol=1e-3)


def test_mha_bf16():
    q = _rand(jax.random.key(0), (1, 32, 4, 32), jnp.bfloat16)
    k = _rand(jax.random.key(1), (1, 32, 2, 32), jnp.bfloat16)
    v = _rand(jax.random.key(2), (1, 32, 2, 32), jnp.bfloat16)
    got = ops.attention(q, k, v, bq=16, bk=16)
    want = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# conv_im2col
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    # (H, W, C, K, R, stride)
    (12, 12, 16, 24, 3, 1),
    (12, 12, 16, 24, 3, 2),
    (8, 8, 8, 32, 1, 1),
    (14, 14, 16, 8, 7, 2),
    (9, 9, 16, 8, 3, 2),        # odd spatial
])
def test_conv_im2col_matches_lax(spec):
    H, W, C, K, R, stride = spec
    x = _rand(jax.random.key(0), (2, H, W, C), jnp.float32)
    w = _rand(jax.random.key(1), (R, R, C, K), jnp.float32)
    got = ops.conv2d(x, w, stride=stride)
    want = ref.conv2d_ref(x, w, stride=stride)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# reshuffle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cb", [8, 32, 128])
def test_blocked_layout(cb):
    x = _rand(jax.random.key(0), (5, 6, 256), jnp.float32)
    np.testing.assert_array_equal(ops.blocked_layout(x, cb),
                                  ref.blocked_layout_ref(x, cb))


def test_blocked_layout_pads_channels():
    x = _rand(jax.random.key(0), (4, 4, 100), jnp.float32)
    out = ops.blocked_layout(x, 128)
    assert out.shape == (1, 4, 4, 128)
    np.testing.assert_array_equal(out[0, :, :, :100], x)
    np.testing.assert_array_equal(out[0, :, :, 100:], 0)


@pytest.mark.parametrize("mn", [(128, 128), (100, 70), (257, 33), (1, 129)])
def test_tiled_transpose(mn):
    x = _rand(jax.random.key(0), mn, jnp.float32)
    np.testing.assert_array_equal(ops.transpose(x), x.T)


def test_on_the_fly_kt_equals_transpose_pass():
    """Voltra claim: the streamer's on-the-fly K^T gives the same math as
    a dedicated transposer pass, with zero extra memory traffic. We verify
    the math side: attention(q, k) == q @ transpose(k) softmaxed."""
    q = _rand(jax.random.key(0), (1, 8, 2, 16), jnp.float32)
    k = _rand(jax.random.key(1), (1, 8, 2, 16), jnp.float32)
    v = _rand(jax.random.key(2), (1, 8, 2, 16), jnp.float32)
    fused = ops.attention(q, k, v, causal=False, bq=8, bk=8)
    # dedicated pass: transpose k with the reshuffler kernel, then score
    s = jnp.einsum("bqhd,bhds->bhqs", q.transpose(0, 1, 2, 3),
                   jnp.stack([jnp.stack([ops.transpose(k[b, :, h, :])
                                         for h in range(2)])
                              for b in range(1)])) * (16 ** -0.5)
    p = jax.nn.softmax(s, axis=-1)
    manual = jnp.einsum("bhqs,bshd->bqhd", p, v)
    np.testing.assert_allclose(fused, manual, rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# maxpool (Sec. II-E aux module)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [
    # (H, W, C, window, stride)
    (8, 8, 16, 2, 2),
    (9, 9, 8, 3, 2),
    (12, 12, 32, 3, 3),
    (10, 10, 8, 5, 1),      # arbitrary window, stride 1
])
def test_maxpool_matches_reduce_window(spec):
    from repro.kernels.maxpool import maxpool2d, maxpool2d_ref
    H, W, C, win, stride = spec
    x = _rand(jax.random.key(0), (2, H, W, C), jnp.float32)
    got = maxpool2d(x, window=win, stride=stride)
    np.testing.assert_array_equal(got, maxpool2d_ref(x, window=win,
                                                     stride=stride))
