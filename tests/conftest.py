import os

# Tests run on the single real CPU device (the dry-run pins 512 placeholder
# devices itself and runs out-of-process; never set that here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is an optional dependency: when it's missing, install a stub
# that turns every @given property test into a clean pytest skip, so the
# plain tests in the same modules still collect and run (a bare import
# error here used to abort collection of the whole suite).
try:
    from hypothesis import settings
except ImportError:
    import sys
    import types

    import pytest

    def _strategy(*args, **kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("booleans", "data", "floats", "integers", "just", "lists",
                  "sampled_from", "text", "tuples"):
        setattr(_st, _name, _strategy)

    def _given(*args, **kwargs):
        def deco(fn):
            # zero-arg replacement: pytest must not try to resolve the
            # property test's strategy parameters as fixtures
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    class _Settings:
        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
else:
    settings.register_profile("ci", max_examples=30, deadline=None)
    settings.load_profile("ci")
