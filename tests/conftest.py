import os

# Tests run on the single real CPU device (the dry-run pins 512 placeholder
# devices itself and runs out-of-process; never set that here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=30, deadline=None)
settings.load_profile("ci")
