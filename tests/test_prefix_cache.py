"""Prefix-sharing KV subsystem: refcounted allocator semantics, radix-tree
match/insert/evict (host-side, no jax), and engine-level exact-greedy
equivalence — prefix caching ON must reproduce the no-sharing engine's
tokens bit-for-bit under both attn_impls, through CoW divergence,
eviction under pool pressure, and preemption."""
import pytest

from repro.runtime.kv_cache import PageAllocator
from repro.runtime.prefix_cache import PrefixCache

# ---------------------------------------------------------------------------
# Refcounted allocator (pure host-side)
# ---------------------------------------------------------------------------


def test_allocate_shared_refcounts_and_frees_last_owner():
    a = PageAllocator(8, 4)
    t0 = a.allocate(0, 12)                 # 3 private pages
    t1 = a.allocate_shared(1, 12, t0[:2])  # shares 2, allocates 1
    assert t1[:2] == t0[:2] and t1[2] != t0[2]
    assert a.ref(t0[0]) == 2 and a.ref(t0[2]) == 1
    assert a.allocated_pages == 4          # 3 + 1 fresh, shared not doubled
    a.check()
    # freeing one owner keeps the shared pages alive for the other
    assert a.free_request(0) == 1          # only its private page freed
    assert a.ref(t1[0]) == 1
    a.check()
    assert a.free_request(1) == 3
    assert a.allocated_pages == 0
    a.check()


def test_allocate_shared_rejection_takes_no_refs():
    a = PageAllocator(3, 4)
    t0 = a.allocate(0, 8)                  # 2 pages, 1 free
    assert a.allocate_shared(1, 16, t0) is None    # needs 2 fresh, has 1
    assert a.ref(t0[0]) == 1               # no refs leaked by the rejection
    a.check()


def test_cache_pin_keeps_page_after_owner_finishes():
    a = PageAllocator(4, 4)
    t = a.allocate(0, 8)
    a.cache_pin(t[0])
    assert a.free_request(0) == 1          # pinned page survives
    assert a.ref(t[0]) == 1 and a.allocated_pages == 1
    assert a.cached_idle_pages == 1
    a.check()
    assert a.cache_unpin(t[0])             # unpin -> actually freed
    assert a.allocated_pages == 0
    a.check()


def test_replace_page_gives_private_copy():
    a = PageAllocator(6, 4)
    t0 = a.allocate(0, 8)
    t1 = a.allocate_shared(1, 8, t0[:1])
    old, new = a.replace_page(1, 0)
    assert old == t0[0] and new not in t0
    assert a.block_table(1)[0] == new
    assert a.ref(old) == 1 and a.ref(new) == 1
    a.check()
    a.check_no_aliasing()                  # nothing shared anymore


def test_check_catches_refcount_drift():
    a = PageAllocator(4, 4)
    t = a.allocate(0, 8)
    a._ref[t[0]] += 1                      # corrupt on purpose
    with pytest.raises(AssertionError):
        a.check()


# ---------------------------------------------------------------------------
# Radix tree (pure host-side; pages come from a real allocator)
# ---------------------------------------------------------------------------


def _setup(num_pages=32, page=4):
    a = PageAllocator(num_pages, page)
    return a, PrefixCache(a)


def test_match_walks_whole_pages_and_caps():
    a, px = _setup()
    toks = list(range(12))                 # 3 full pages of 4
    table = a.allocate(0, 12)
    assert px.insert(toks, table) == 3
    m = px.match(toks + [99], max_tokens=12)
    assert m.pages == table and m.tokens == 12 and m.partial_page is None
    # cap: an identical prompt may not match itself entirely — the last
    # page degrades to a partial (CoW) hit so one token remains to prefill
    m = px.match(toks, max_tokens=11)
    assert m.pages == table[:2]
    assert m.partial_page == table[2] and m.partial_tokens == 3
    assert m.tokens == 11


def test_match_divergence_inside_page_is_partial_hit():
    a, px = _setup()
    toks = [1, 2, 3, 4, 5, 6, 7, 8]
    table = a.allocate(0, 8)
    px.insert(toks, table)
    m = px.match([1, 2, 3, 4, 5, 6, 99, 98, 97], max_tokens=8)
    assert m.pages == table[:1]
    assert m.partial_page == table[1] and m.partial_tokens == 2
    assert m.tokens == 6
    # divergence in the FIRST page: no full pages, partial only
    m = px.match([1, 2, 99, 98], max_tokens=4)
    assert m.pages == [] and m.partial_tokens == 2


def test_match_is_pure_until_committed():
    """match() alone moves no telemetry and no LRU state — only commit()
    does, once per successful admission."""
    a, px = _setup()
    toks = list(range(8))
    px.insert(toks, a.allocate(0, 8))
    for _ in range(5):                     # five rejected-admission retries
        m = px.match(toks, max_tokens=7)
    assert px.lookups == 0 and px.hits == 0 and px.hit_tokens == 0
    px.commit(m, 8)
    assert px.lookups == 1 and px.hits == 1 and px.hit_tokens == 7
    px.reset_hit_counters()
    assert px.lookups == px.hits == px.hit_tokens == 0
    assert px.cached_pages == 2            # tree contents survive the reset


def test_insert_skips_duplicate_chunks():
    a, px = _setup()
    toks = [5, 5, 5, 5]
    t0 = a.allocate(0, 4)
    t1 = a.allocate(1, 4)
    assert px.insert(toks, t0) == 1
    assert px.insert(toks, t1) == 0        # incumbent kept, no double pin
    assert a.ref(t0[0]) == 2 and a.ref(t1[0]) == 1
    a.check()


def test_evict_lru_leaves_first_and_protect():
    a, px = _setup(num_pages=32, page=4)
    ta = a.allocate(0, 8)                  # chain A: 2 pages
    px.insert([1, 2, 3, 4, 5, 6, 7, 8], ta)
    tb = a.allocate(1, 4)                  # chain B: 1 page
    px.insert([9, 9, 9, 9], tb)
    a.free_request(0)
    a.free_request(1)                      # everything idle now
    assert a.cached_idle_pages == 3
    # a committed match on chain A refreshes its LRU clock -> B is LRU.
    # (An uncommitted match must NOT: rejected admissions retried every
    # scheduler tick may not keep a stalled request's prefix hot.)
    m = px.match([1, 2, 3, 4, 5, 6, 7, 8])
    px.commit(m, 8)
    assert px.evict(1) == 1
    assert a.ref(tb[0]) == 0               # B's page went first
    # chain A: the leaf (page 2) must be evicted before its parent
    assert px.evict(1) == 1
    assert a.ref(ta[1]) == 0 and a.ref(ta[0]) == 1
    # protect shields a page mid-admission
    assert px.evict(1, protect={ta[0]}) == 0
    assert px.evict(1) == 1
    assert a.allocated_pages == 0
    a.check()


def test_evictable_count_is_a_dry_run_and_respects_structure():
    a, px = _setup()
    ta = a.allocate(0, 8)                  # parent + leaf
    px.insert([1, 2, 3, 4, 5, 6, 7, 8], ta)
    tb = a.allocate(1, 4)
    px.insert([9, 9, 9, 9], tb)
    # everything still owned by live tables -> nothing evictable
    assert px.evictable_count() == 0
    a.free_request(1)
    assert px.evictable_count() == 1       # B idle; A's pages still owned
    a.free_request(0)
    assert px.evictable_count() == 3       # leaf-first peeling reaches all
    assert px.evictable_count(protect={ta[0]}) == 2
    # protecting the LEAF blocks its parent too (leaf-first order)
    assert px.evictable_count(protect={ta[1]}) == 1
    assert px.cached_pages == 3            # dry run: nothing moved
    a.check()


def test_evict_spares_pages_still_referenced():
    a, px = _setup()
    t0 = a.allocate(0, 4)
    px.insert([1, 2, 3, 4], t0)            # ref: table + pin = 2
    assert px.evict(5) == 0                # in use -> not evictable
    a.free_request(0)
    assert px.evict(5) == 1
    a.check()


# ---------------------------------------------------------------------------
# Engine-level (jax; small smoke model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def qwen():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import api
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, api.init_params(cfg, jax.random.key(0))


SYS = [7, 3, 9, 1, 4, 4, 2, 8, 6, 5]       # shared 10-token system prompt


def _mk_shared(max_new=5):
    from repro.runtime.serving import Request
    # rid 0/1: shared 10-token prefix, divergent tails (full-page hits +
    # mid-page CoW at page_size=4); rid 2: identical to rid 0's prompt
    # (the full-match-capped CoW case); rid 3: no overlap at all
    return [Request(rid=0, prompt=SYS + [11, 12], max_new=max_new),
            Request(rid=1, prompt=SYS + [13, 14, 15], max_new=max_new),
            Request(rid=2, prompt=SYS + [11, 12], max_new=max_new),
            Request(rid=3, prompt=[9, 8, 7, 6, 5], max_new=max_new)]


def _run(cfg, params, reqs, *, impl, share, max_steps=400, **kw):
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import PagedServingEngine
    eng = PagedServingEngine(cfg, params, slots=kw.pop("slots", 2),
                             max_len=32, page_size=kw.pop("page_size", 4),
                             attn_impl=impl, prefix_cache=share, **kw)
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)
    sched.drain(max_steps=max_steps)
    eng.check()
    return eng, sched


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["gather", "kernel"])
def test_prefix_cache_exact_greedy_equivalence(qwen, impl):
    """Decoded tokens with prefix sharing ON are identical to the
    no-sharing engine, per request, under both decode attention impls —
    covering full-page hits, mid-page CoW divergence, and an identical
    resubmitted prompt."""
    cfg, params = qwen
    want_reqs = _mk_shared()
    _run(cfg, params, want_reqs, impl=impl, share=False)
    want = {r.rid: r.generated for r in want_reqs}

    got_reqs = _mk_shared()
    eng, _ = _run(cfg, params, got_reqs, impl=impl, share=True)
    assert {r.rid: r.generated for r in got_reqs} == want
    ps = eng.prefix_stats()
    assert ps["hits"] >= 2                 # rid 1 and rid 2 (at least)
    assert ps["cow_copies"] >= 1           # rid 2's identical prompt
    assert ps["prefilled_tokens"] < ps["prompt_tokens"]
    assert ps["prefill_tokens_saved"] == ps["hit_tokens"]


@pytest.mark.slow
def test_prefix_cache_eviction_under_pool_pressure(qwen):
    """With a pool too small to keep every cached page, idle prefix pages
    are evicted (before any preemption) and outputs still match the
    no-sharing engine exactly."""
    cfg, params = qwen
    want_reqs = _mk_shared(max_new=6)
    _run(cfg, params, want_reqs, impl="gather", share=False, num_pages=9)
    want = {r.rid: r.generated for r in want_reqs}

    got_reqs = _mk_shared(max_new=6)
    eng, _ = _run(cfg, params, got_reqs, impl="gather", share=True,
                  num_pages=9)
    assert {r.rid: r.generated for r in got_reqs} == want
    assert eng.prefix.evicted_pages >= 1
    eng.alloc.check()


@pytest.mark.slow
def test_prefix_cache_with_preemption_resumes_exactly(qwen):
    """Decode growth outruns a tiny pool: requests get preempted and
    resumed (re-matching their own cached prefix on resubmit) — outputs
    must still equal the no-sharing engine's."""
    cfg, params = qwen
    want_reqs = _mk_shared(max_new=8)
    _run(cfg, params, want_reqs, impl="gather", share=False, num_pages=8,
         slots=3)
    want = {r.rid: r.generated for r in want_reqs}

    got_reqs = _mk_shared(max_new=8)
    eng, sched = _run(cfg, params, got_reqs, impl="gather", share=True,
                      num_pages=8, slots=3)
    assert {r.rid: r.generated for r in got_reqs} == want
    assert sched.preempted >= 1
    assert eng.alloc.live_requests == 0
    eng.alloc.check()


@pytest.mark.slow
def test_prefix_cache_saves_peak_pages(qwen):
    """The structural claim: with heavy prompt overlap, sharing serves the
    same trace with fewer peak physical pages AND fewer prefilled tokens
    than private paging."""
    from repro.runtime.serving import Request
    cfg, params = qwen
    sys32 = [(3 * j + 1) % cfg.vocab for j in range(16)]

    def mk():
        return [Request(rid=i, prompt=sys32 + [50 + i], max_new=3)
                for i in range(4)]

    base_reqs = mk()
    base, _ = _run(cfg, params, base_reqs, impl="gather", share=False,
                   slots=4)
    pref_reqs = mk()
    pref, _ = _run(cfg, params, pref_reqs, impl="gather", share=True,
                   slots=4)
    assert ({r.rid: r.generated for r in pref_reqs}
            == {r.rid: r.generated for r in base_reqs})
    assert pref.alloc.peak_pages < base.alloc.peak_pages
    assert pref.prefilled_tokens < base.prefilled_tokens


# ---------------------------------------------------------------------------
# Scheduler drain loudness (satellite)
# ---------------------------------------------------------------------------


class _WedgedEngine:
    """Never admits, never finishes: drain's budget must trip loudly."""

    def submit(self, req):
        return False

    def step(self):
        return []

    def has_live(self):
        return False


def test_drain_raises_on_exhausted_budget():
    from repro.runtime.scheduler import Scheduler, SchedulerExhausted
    from repro.runtime.serving import Request
    sched = Scheduler(_WedgedEngine())
    sched.add(Request(rid=0, prompt=[1, 2], max_new=4))
    with pytest.raises(SchedulerExhausted, match="1 pending"):
        sched.drain(max_steps=3)
    assert sched.exhausted


def test_drain_warn_mode_sets_telemetry():
    from repro.runtime.scheduler import Scheduler
    from repro.runtime.serving import Request
    sched = Scheduler(_WedgedEngine())
    sched.add(Request(rid=0, prompt=[1, 2], max_new=4))
    with pytest.warns(UserWarning, match="exhausted"):
        sched.drain(max_steps=3, on_exhaust="warn")
    assert sched.exhausted
