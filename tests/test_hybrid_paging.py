"""Hybrid/windowed stacks through the paged serving engine (ISSUE 5).

Covers: the sliding-window allocator extensions (base blocks /
release_prefix), window-page recycling bounds, paged-vs-dense greedy
equivalence on a griffin-style hybrid (both attn impls, prompts longer
than the window, preemption-resume, int8 KV, speculative decode with
recurrent-state rollback), bucket-padded recurrent prefill state masking,
and the ISSUE 5 satellite regressions: the engine factory's loud dense
fallback, the windowed multi-token ValueError (no bare assert), and the
int8 windowed prefill->decode round trip."""
import dataclasses
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import api, griffin, ssm
from repro.models.layers import Maker, attend_decode
from repro.runtime.kv_cache import PageAllocator
from repro.runtime.scheduler import Scheduler
from repro.runtime.serving import (DenseServingEngine, PagedServingEngine,
                                   Request, ServingEngine)


def _hybrid_cfg(**over):
    cfg = get_smoke_config("recurrentgemma-9b")
    return dataclasses.replace(cfg, **over) if over else cfg


def _mk_reqs(max_new=8, window=16):
    # rid 0's prompt straddles the window (28 > 16); rid 1 sits under it
    return [Request(rid=0, prompt=[5, 4, 3, 2, 1, 6, 7] * 4,
                    max_new=max_new),
            Request(rid=1, prompt=[1, 2, 3, 4, 5, 6], max_new=max_new)]


# ---------------------------------------------------------------------------
# Allocator: sliding-window tables (pure host-side)
# ---------------------------------------------------------------------------


def test_allocate_with_base_blocks_skips_pre_window_pages():
    a = PageAllocator(8, 4)
    # 30-token prompt, window leaves blocks 0..4 dead: only 3 live pages
    t = a.allocate(0, 30, base_blocks=5)
    assert len(t) == 3 and a.allocated_pages == 3
    assert a.base_blocks(0) == 5
    assert a.tokens(0) == 30
    assert a.live_tokens == 30 - 5 * 4      # tokens resident in live pages
    a.check()


def test_release_prefix_recycles_and_preserves_logical_indexing():
    a = PageAllocator(8, 4)
    t = a.allocate(0, 16)                   # blocks 0..3
    assert a.release_prefix(0, 2) == 2      # blocks 0,1 slid out
    assert a.base_blocks(0) == 2
    assert a.block_table(0) == t[2:]
    assert a.free_pages == 6
    # extend_to keeps counting in ABSOLUTE tokens: block 4 is next
    got = a.extend_to(0, 17)
    assert got not in (0, None)
    assert a.block_table(0) == t[2:] + [got]
    a.check()
    # recycled pages are immediately reissuable to others
    assert a.allocate(1, 20) is not None
    a.check()


def test_release_prefix_must_keep_one_block():
    a = PageAllocator(4, 4)
    a.allocate(0, 8)                        # 2 pages
    with pytest.raises(AssertionError):
        a.release_prefix(0, 2)
    a.release_prefix(0, 1)
    a.check()


def test_truncate_respects_window_base():
    a = PageAllocator(8, 4)
    a.allocate(0, 24, base_blocks=3)        # blocks 3..5 live
    a.extend_to(0, 25)                      # block 6
    assert a.truncate_to(0, 24) == 1        # spec rollback drops block 6
    assert a.base_blocks(0) == 3 and len(a.block_table(0)) == 3
    with pytest.raises(AssertionError):
        a.truncate_to(0, 8)                 # would roll back past the base
    a.check()
    a.free_request(0)
    assert a.allocated_pages == 0
    a.check()


def test_windowed_interleaving_keeps_invariants():
    """allocate/extend/release/truncate/free interleaving on a windowed
    table preserves every pool invariant and ends fully reclaimed."""
    page, window = 4, 10
    a = PageAllocator(6, page)
    a.allocate(0, 7)
    for pos in range(7, 40):
        # recycle blocks fully below pos - window + 1, keeping >= 1
        dead = max(0, pos - window + 1) // page
        n = min(dead - a.base_blocks(0), len(a.block_table(0)) - 1)
        if n > 0:
            a.release_prefix(0, n)
        got = a.extend_to(0, pos + 1)
        assert got is not None, "recycling must keep the pool ahead"
        live = len(a.block_table(0))
        assert live <= window // page + 2
        a.check()
    a.free_request(0)
    assert a.allocated_pages == 0
    a.check()


# ---------------------------------------------------------------------------
# Recurrent prefill state masking + multi-token decode checkpoints
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "mamba2-2.7b"])
def test_padded_prefill_state_equals_exact_prefill(arch):
    """Bucket-padded prefill with paged_kv + length must yield the SAME
    recurrent state as exact-length prefill — the property that makes
    one-trace-per-bucket prefill legal for recurrent stacks."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 11), 0, cfg.vocab)
    lg_e, cache_e, _ = api.prefill(cfg, params, {"tokens": toks})
    padded = jnp.pad(toks, ((0, 0), (0, 5)))
    lg_p, cache_p, pos_p = api.prefill(cfg, params, {"tokens": padded},
                                       length=11, paged_kv=True)
    assert int(pos_p[0]) == 11
    np.testing.assert_allclose(np.asarray(lg_e), np.asarray(lg_p),
                               rtol=1e-5, atol=1e-5)
    import jax.tree_util as jtu
    flat_e = jtu.tree_flatten_with_path(cache_e)[0]
    flat_p = jtu.tree_flatten_with_path(cache_p)[0]
    state_names = {"'h'", "'conv'", "'ssm'"}    # recurrent-state leaves
    checked = 0
    for (pe, e), (pp, p) in zip(flat_e, flat_p):
        name = jtu.keystr(pe).rsplit("[", 1)[-1].rstrip("]")
        if name in state_names:     # kv leaves differ by layout (ring vs
            checked += 1            # full) — only states must be equal
            np.testing.assert_allclose(np.asarray(e, np.float32),
                                       np.asarray(p, np.float32),
                                       rtol=1e-4, atol=1e-4,
                                       err_msg=jtu.keystr(pe))
    assert checked > 0


def test_rglru_multitoken_decode_checkpoints_match_single_steps():
    cfg = dataclasses.replace(_hybrid_cfg(), dtype="float32")
    mk = Maker("init", jax.random.key(0), jnp.float32)
    p = griffin.rglru_init(mk, cfg)
    B, T = 2, 4
    x = jax.random.normal(jax.random.key(1), (B, T, cfg.d_model), jnp.float32)
    cache = griffin.rglru_cache_init(cfg, B)
    out_blk, ck = griffin.rglru_decode(cfg, p, x, cache)
    assert ck["h"].shape[:2] == (B, T)          # checkpointed T axis
    c = cache
    for t in range(T):
        out_t, c = griffin.rglru_decode(cfg, p, x[:, t:t + 1], c)
        np.testing.assert_allclose(np.asarray(out_blk[:, t]),
                                   np.asarray(out_t[:, 0]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ck["h"][:, t]),
                                   np.asarray(c["h"]), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ck["conv"][:, t]),
                                   np.asarray(c["conv"]),
                                   rtol=1e-5, atol=1e-5)


def test_ssm_multitoken_decode_checkpoints_match_single_steps():
    cfg = dataclasses.replace(get_smoke_config("mamba2-2.7b"),
                              dtype="float32")
    mk = Maker("init", jax.random.key(0), jnp.float32)
    p = ssm.ssm_init(mk, cfg)
    B, T = 2, 3
    x = jax.random.normal(jax.random.key(1), (B, T, cfg.d_model), jnp.float32)
    cache = ssm.ssm_cache_init(cfg, B)
    out_blk, ck = ssm.ssm_decode(cfg, p, x, cache)
    assert ck["ssm"].shape[:2] == (B, T)
    c = cache
    for t in range(T):
        out_t, c = ssm.ssm_decode(cfg, p, x[:, t:t + 1], c)
        np.testing.assert_allclose(np.asarray(out_blk[:, t]),
                                   np.asarray(out_t[:, 0]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ck["ssm"][:, t]),
                                   np.asarray(c["ssm"]),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Engine: hybrid stacks through the paged engine
# ---------------------------------------------------------------------------


def test_factory_routes_hybrid_to_paged_engine():
    cfg = _hybrid_cfg()
    params = api.init_params(cfg, jax.random.key(0))
    eng = ServingEngine(cfg, params, slots=2, max_len=64)
    assert isinstance(eng, PagedServingEngine)
    assert eng.has_win and eng.has_state and not eng.has_full


def test_hybrid_paged_matches_dense_greedy_gather():
    """Greedy outputs of the paged hybrid engine == the dense baseline,
    token for token, including prompts longer than the window (fp32: the
    masked-page softmax reorders accumulation vs the dense ring, so bf16
    bit equality is not the contract — same policy as the full-attention
    kernel equivalence tests)."""
    cfg = _hybrid_cfg(dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    dense = DenseServingEngine(cfg, params, slots=2, max_len=64)
    want = {r.rid: r.generated
            for r in dense.run_to_completion(_mk_reqs(), max_steps=120)}
    eng = PagedServingEngine(cfg, params, slots=2, max_len=64, page_size=4,
                             attn_impl="gather")
    reqs = _mk_reqs()
    eng.run_to_completion(reqs, max_steps=400)
    assert {r.rid: r.generated for r in reqs} == want
    eng.check()
    assert eng.alloc.allocated_pages == 0   # all pages reclaimed


@pytest.mark.slow
def test_hybrid_paged_matches_dense_greedy_kernel():
    """Same equivalence on the Pallas flash-decode path: the kernel's
    window masking + below-window page skipping must reproduce the dense
    ring buffer's greedy tokens exactly (fp32), and recycling must have
    actually run (the prompt slides past the window)."""
    cfg = _hybrid_cfg(dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    dense = DenseServingEngine(cfg, params, slots=2, max_len=64)
    want = {r.rid: r.generated
            for r in dense.run_to_completion(_mk_reqs(20), max_steps=200)}
    eng = PagedServingEngine(cfg, params, slots=2, max_len=64, page_size=4,
                             attn_impl="kernel")
    reqs = _mk_reqs(20)
    eng.run_to_completion(reqs, max_steps=600)
    assert {r.rid: r.generated for r in reqs} == want
    assert eng.win_recycled_pages > 0
    eng.check()


def test_hybrid_window_pages_stay_o_window():
    """The headline bound: live window pages per request never exceed
    ceil((window + 1)/page) + 1 however long decode runs — the engine
    recycles pages as they slide out (ISSUE 5 acceptance criterion)."""
    cfg = _hybrid_cfg(dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    eng = PagedServingEngine(cfg, params, slots=2, max_len=128, page_size=4,
                             attn_impl="gather")
    reqs = [Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new=60),
            Request(rid=1, prompt=[2, 7, 1] * 8, max_new=60)]
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)
    bound = eng.win_pages_bound(eng.max_len)
    peak_live = 0
    while sched.pending or eng.has_live():
        sched.tick()
        for r in eng.live:
            if r is not None:
                live = len(eng.alloc.block_table(("win", r.rid)))
                peak_live = max(peak_live, live)
                assert live <= bound
        eng.check()                 # includes the O(window) assertion
    assert all(r.done for r in reqs)
    assert eng.win_recycled_pages > 0
    # decode ran far past the window: without recycling each request
    # would hold pages_for(65) = 17 pages; the bound is much tighter
    assert peak_live <= bound < eng.alloc.pages_for(65)


@pytest.mark.slow
def test_hybrid_preemption_resume_matches_dense():
    """A pool sized to force preemption: evicted hybrid requests resume
    by re-prefill (window pages re-admitted pre-recycled, recurrent state
    rebuilt) and still match the dense baseline exactly."""
    cfg = _hybrid_cfg(dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    dense = DenseServingEngine(cfg, params, slots=2, max_len=64)
    want = {r.rid: r.generated
            for r in dense.run_to_completion(_mk_reqs(20), max_steps=200)}
    eng = PagedServingEngine(cfg, params, slots=2, max_len=64, page_size=4,
                             num_pages=7, attn_impl="gather")
    reqs = _mk_reqs(20)
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)
    sched.drain(max_steps=800)
    assert sched.preempted >= 1             # the pool is sized to force it
    assert {r.rid: r.generated for r in reqs} == want
    assert eng.alloc.allocated_pages == 0


@pytest.mark.slow
def test_hybrid_int8_paged_matches_dense():
    """int8 KV pools on the windowed paged path (kernel dequantizes
    tile-by-tile; gather path via kv_dequant) reproduce the dense int8
    ring buffer's greedy tokens."""
    cfg = _hybrid_cfg(dtype="float32", kv_cache_dtype="int8", kv_scale=8.0)
    params = api.init_params(cfg, jax.random.key(0))
    dense = DenseServingEngine(cfg, params, slots=2, max_len=64)
    want = {r.rid: r.generated
            for r in dense.run_to_completion(_mk_reqs(12), max_steps=200)}
    for impl in ("gather", "kernel"):
        eng = PagedServingEngine(cfg, params, slots=2, max_len=64,
                                 page_size=4, attn_impl=impl)
        reqs = _mk_reqs(12)
        eng.run_to_completion(reqs, max_steps=400)
        assert {r.rid: r.generated for r in reqs} == want, impl


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "mamba2-2.7b"])
def test_hybrid_speculative_matches_plain_greedy(arch):
    """spec_k on recurrent/hybrid stacks: the verify step's checkpointed
    recurrent states + window/page rollback reproduce the plain engine's
    greedy tokens exactly. An oracle drafter (the true continuation)
    forces near-total acceptance, so the state-select path is exercised
    at every accept length — the n-gram drafter alone rarely hits on a
    random-init model."""
    from repro.runtime import serving as serving_mod
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    prompts = {0: [3, 1, 4, 1, 5], 1: [2, 7, 1, 8]}

    def mk():
        return [Request(rid=r, prompt=list(p), max_new=24)
                for r, p in prompts.items()]

    dense = DenseServingEngine(cfg, params, slots=2, max_len=128)
    want = {r.rid: r.generated
            for r in dense.run_to_completion(mk(), max_steps=400)}

    # plain n-gram drafting first: exactness must hold at any accept rate
    eng = PagedServingEngine(cfg, params, slots=2, max_len=128, page_size=8,
                             attn_impl="gather", spec_k=3)
    reqs = mk()
    eng.run_to_completion(reqs, max_steps=400)
    assert {r.rid: r.generated for r in reqs} == want
    eng.check()

    full = {rid: list(p) + want[rid] for rid, p in prompts.items()}

    def oracle(ctx, k, max_ngram=3):
        for seq in full.values():
            if seq[: len(ctx)] == list(ctx):
                return seq[len(ctx): len(ctx) + k]
        return []

    orig = serving_mod.ngram_propose
    serving_mod.ngram_propose = oracle
    try:
        eng = PagedServingEngine(cfg, params, slots=2, max_len=128,
                                 page_size=8, attn_impl="gather", spec_k=4)
        reqs = mk()
        eng.run_to_completion(reqs, max_steps=400)
        assert {r.rid: r.generated for r in reqs} == want
        assert eng.spec_stats()["accept_rate"] > 0.9
        eng.check()
    finally:
        serving_mod.ngram_propose = orig


def test_hybrid_rejects_prefix_cache():
    cfg = _hybrid_cfg()
    params = api.init_params(cfg, jax.random.key(0))
    with pytest.raises(ValueError, match="prefix_cache"):
        PagedServingEngine(cfg, params, slots=2, max_len=64,
                           prefix_cache=True)


# ---------------------------------------------------------------------------
# Satellite: loud dense fallback in the ServingEngine factory
# ---------------------------------------------------------------------------


def test_factory_dense_fallback_warns_naming_dropped_kwargs():
    """The factory used to pop the paged feature kwargs silently when
    falling back to the dense engine — the caller asked for features and
    got no signal they were dropped."""
    cfg = get_smoke_config("seamless-m4t-large-v2")     # enc-dec: dense
    params = api.param_shapes(cfg)      # engine init never touches params
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = ServingEngine(cfg, params, slots=2, max_len=32,
                            prefix_cache=True, attn_impl="gather",
                            page_size=8)
    assert isinstance(eng, DenseServingEngine)
    msgs = [str(x.message) for x in w]
    assert any("prefix_cache" in m and "attn_impl" in m
               and "page_size" in m for m in msgs), msgs


def test_factory_dense_fallback_raises_on_spec_k():
    """spec_k changes output semantics (verify-step stats, multi-token
    acceptance) — dropping it silently is worse than a warning."""
    cfg = get_smoke_config("seamless-m4t-large-v2")
    params = api.param_shapes(cfg)
    with pytest.raises(ValueError, match="spec_k"):
        ServingEngine(cfg, params, slots=2, max_len=32, spec_k=4)
    # kwargs still at their paged defaults (features never requested)
    # fall back QUIETLY — launchers pass the whole knob set every call,
    # and warning on never-enabled features would drown the real signal
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = ServingEngine(cfg, params, slots=2, max_len=32, spec_k=0,
                            page_size=16, prefix_cache=False)
    assert isinstance(eng, DenseServingEngine)
    assert not w, [str(x.message) for x in w]


# ---------------------------------------------------------------------------
# Satellite: windowed multi-token decode fails loudly (no bare assert)
# ---------------------------------------------------------------------------


def test_multitoken_windowed_dense_raises_value_error():
    """spec-style T > 1 blocks meeting a local_attn ring buffer used to
    die with a bare `assert Tq == 1` deep inside the jit trace; now
    api.decode_step rejects them up front, naming the layer kind."""
    cfg = _hybrid_cfg(dtype="float32")
    params = api.init_params(cfg, jax.random.key(0))
    _, cache, pos = api.prefill(cfg, params,
                                {"tokens": jnp.ones((1, 6), jnp.int32)},
                                max_len=32)
    with pytest.raises(ValueError, match="local_attn"):
        api.decode_step(cfg, params, cache, jnp.ones((1, 3), jnp.int32),
                        pos)


def test_multitoken_full_attention_without_table_raises():
    cfg = get_smoke_config("qwen2.5-3b")
    params = api.init_params(cfg, jax.random.key(0))
    _, cache, pos = api.prefill(cfg, params,
                                {"tokens": jnp.ones((1, 6), jnp.int32)},
                                max_len=32)
    with pytest.raises(ValueError, match="attn_mlp"):
        api.decode_step(cfg, params, cache, jnp.ones((1, 3), jnp.int32),
                        pos)


def test_attend_decode_ring_rejects_multitoken_block():
    q = jnp.zeros((1, 2, 4, 8))
    ck = cv = jnp.zeros((1, 16, 2, 8))
    with pytest.raises(ValueError, match="single-token"):
        attend_decode(q, ck, cv, jnp.array([4]), window=16, ring=True)


# ---------------------------------------------------------------------------
# Satellite: int8 KV through the windowed scatter
# ---------------------------------------------------------------------------


def test_int8_window_cache_roundtrips_bitwise_fp32():
    """_window_cache applies kv_quant per entry before the ring scatter;
    with fp32 params the cache built by prefill must BITWISE match the
    cache built by decoding the same tokens one-by-one — i.e. the scatter
    itself (gathered pos rows, slot mapping, scale handling) is exact.
    (Under bf16 params the values themselves wobble +-1 quant step from
    batched-vs-single matmul accumulation — identically on the full-
    attention path, so that is a numerics property, not a window bug;
    the teacher-forcing test below covers that regime.)"""
    cfg = _hybrid_cfg(dtype="float32", kv_cache_dtype="int8", kv_scale=8.0)
    params = api.init_params(cfg, jax.random.key(0))
    T, split = 22, 19                       # both sides > window (16)
    toks = jax.random.randint(jax.random.key(1), (1, T), 0, cfg.vocab)
    _, cache_a, _ = api.prefill(cfg, params, {"tokens": toks},
                                max_len=T + 4)
    _, cache_b, pos = api.prefill(cfg, params, {"tokens": toks[:, :split]},
                                  max_len=T + 4)
    for t in range(split, T):
        _, cache_b = api.decode_step(cfg, params, cache_b,
                                     toks[:, t:t + 1], pos)
        pos = pos + 1
    for a, b in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
        if a.dtype == jnp.int8:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_int8_windowed_prefill_decode_teacher_forcing():
    """End-to-end int8 windowed equivalence: prefill past the window,
    then decode teacher-forced tokens — logits must match the full
    forward pass within the int8 quantization tolerance."""
    cfg = _hybrid_cfg(kv_cache_dtype="int8", kv_scale=8.0)   # bf16 params
    params = api.init_params(cfg, jax.random.key(0))
    T, prefix = 28, 22                      # both > window (16)
    toks = jax.random.randint(jax.random.key(1), (1, T), 0, cfg.vocab)
    full_logits, _, _ = api.forward(cfg, params, {"tokens": toks})
    tol = dict(rtol=3e-2, atol=8e-2)
    logits_p, cache, pos = api.prefill(cfg, params,
                                       {"tokens": toks[:, :prefix]},
                                       max_len=T + 4)
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(full_logits[:, prefix - 1],
                                          np.float32), **tol)
    for t in range(prefix, T):
        logits_d, cache = api.decode_step(cfg, params, cache,
                                          toks[:, t:t + 1], pos)
        pos = pos + 1
        np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                                   np.asarray(full_logits[:, t],
                                              np.float32), **tol)
