"""Unified metrics surface (ISSUE 8): one key set across every engine
configuration, sane latency/utilization numbers, a single warm-up reset
point, and engine-driven traces that pass schema validation."""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.runtime.scheduler import Scheduler
from repro.runtime.serving import (DenseServingEngine, PagedServingEngine,
                                   Request)
from repro.runtime.trace import NULL_TRACER, Tracer, validate_trace

SLOTS, MAX_LEN, MAX_NEW = 2, 32, 3


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("qwen2.5-3b")
    return cfg, api.init_params(cfg, jax.random.key(0))


def _reqs(n=3, max_new=MAX_NEW):
    return [Request(rid=i, prompt=[2 + 3 * i + j for j in range(2 + 2 * i)],
                    max_new=max_new)
            for i in range(n)]


def _run(eng, n=3):
    reqs = _reqs(n)
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)
    sched.drain(max_steps=200)
    return reqs


def _build_all(cfg, params):
    mk = dict(slots=SLOTS, max_len=MAX_LEN)
    return {
        "paged": PagedServingEngine(cfg, params, **mk),
        "paged+prefix": PagedServingEngine(cfg, params, prefix_cache=True,
                                           **mk),
        "paged+spec": PagedServingEngine(cfg, params, spec_k=3, **mk),
        "paged+tier": PagedServingEngine(cfg, params, host_tier=True, **mk),
        "dense": DenseServingEngine(cfg, params, **mk),
    }


@pytest.fixture(scope="module")
def driven(model):
    cfg, params = model
    engines = _build_all(cfg, params)
    for eng in engines.values():
        _run(eng)
    return engines


def test_metrics_key_set_identical_across_configs(driven):
    """The contract dashboards and CSV columns ride on: every engine and
    every feature combination reports the SAME flat key set — subsystems
    that are off report zeros, never missing keys."""
    key_sets = {name: set(e.metrics().keys()) for name, e in driven.items()}
    ref_name, ref = next(iter(key_sets.items()))
    for name, ks in key_sets.items():
        assert ks == ref, (
            f"{name} metrics keys diverge from {ref_name}: "
            f"only-in-{name}={sorted(ks - ref)}, "
            f"missing={sorted(ref - ks)}")
    # the namespaces the consolidation promises are all present
    for ns in ("engine.", "latency.", "util.", "pool.", "spec.",
               "prefix.", "tier.", "shard."):
        assert any(k.startswith(ns) for k in ref), f"no {ns}* keys"


def test_subsystem_stats_key_sets_stable(driven):
    """Each ``*_stats()`` method returns the same keys whether its
    subsystem is on or off (zeros when off)."""
    for meth in ("pool_stats", "spec_stats", "prefix_stats", "tier_stats",
                 "shard_stats"):
        sets = {}
        for name, eng in driven.items():
            st = getattr(eng, meth)()
            sets[name] = set(st.keys()) if isinstance(st, dict) \
                else set(vars(st).keys())
        ref = sets["paged"]
        for name, ks in sets.items():
            assert ks == ref, f"{meth} keys differ: paged vs {name}"
    # off-configs really report zeros, not stale values
    plain = driven["paged"]
    assert plain.prefix_stats()["hits"] == 0
    assert plain.tier_stats()["host_tier"] == 0.0
    assert driven["dense"].spec_stats()["spec_drafted"] == 0.0


def test_latency_and_utilization_sane(driven):
    for name, eng in driven.items():
        m = eng.metrics()
        assert m["latency.requests"] == 3.0, name
        assert m["latency.ttft_p50_s"] > 0.0, name
        assert m["latency.ttft_p95_s"] >= m["latency.ttft_p50_s"], name
        # every request emitted MAX_NEW >= 2 tokens, so TPOT has samples
        assert m["latency.tpot_p50_s"] > 0.0, name
        assert m["latency.tpot_p95_s"] >= m["latency.tpot_p50_s"], name
        # temporal utilization is a ratio of nested wall intervals
        assert 0.0 < m["util.temporal"] <= 1.0, name
        assert m["util.step_wall_s"] <= m["util.tick_wall_s"], name
        # the first token per request comes out of prefill, the rest out
        # of decode steps
        assert m["engine.decoded_tokens"] >= 3 * (MAX_NEW - 1), name


def test_ttft_includes_queue_wait(model):
    """Arrival is stamped at Scheduler.add (enqueue), not at admission:
    a request stuck behind a full engine accrues TTFT while it queues."""
    cfg, params = model
    eng = PagedServingEngine(cfg, params, slots=1, max_len=MAX_LEN)
    reqs = _reqs(3)
    sched = Scheduler(eng)
    for r in reqs:
        sched.add(r)                      # 3 requests, 1 slot: 2 queue
    sched.drain(max_steps=200)
    m = eng.metrics()
    ttfts = sorted(eng.first_token_at[r.rid] - eng._arrival_at[r.rid]
                   for r in reqs)
    # the queued requests waited for a predecessor's full generation
    assert ttfts[-1] > ttfts[0]
    assert m["latency.ttft_p95_s"] >= m["latency.ttft_p50_s"]


def test_reset_metrics_is_the_single_reset_point(model):
    cfg, params = model
    eng = PagedServingEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                             prefix_cache=True)
    _run(eng)
    assert eng.decode_steps > 0 and eng.prefilled_tokens > 0
    traces_before = eng.prefill_traces
    eng.reset_metrics()
    m = eng.metrics()
    assert m["engine.decode_steps"] == 0.0
    assert m["engine.decoded_tokens"] == 0.0
    assert m["latency.requests"] == 0.0
    assert m["latency.ttft_p50_s"] == 0.0
    assert m["util.step_wall_s"] == 0.0 and m["util.temporal"] == 0.0
    # subsystem counters reset through the same call
    assert eng.prefilled_tokens == 0 and eng.prompt_tokens == 0
    assert eng.alloc.share_events == 0
    assert eng.prefix_stats()["lookups"] == 0
    # lifetime facts survive: jit retrace identity is not a per-phase rate
    assert eng.prefill_traces == traces_before
    # and the engine still serves correctly after a reset
    reqs = _run(eng)
    assert all(len(r.generated) == MAX_NEW for r in reqs)
    assert eng.metrics()["latency.requests"] == 3.0


def test_engine_without_tracer_uses_null_tracer(driven):
    for eng in driven.values():
        assert eng.trace is NULL_TRACER


@pytest.mark.parametrize("kind", ["paged", "dense"])
def test_engine_run_produces_valid_trace(model, kind):
    cfg, params = model
    tr = Tracer(enabled=True)
    cls = PagedServingEngine if kind == "paged" else DenseServingEngine
    eng = cls(cfg, params, slots=SLOTS, max_len=MAX_LEN, tracer=tr)
    reqs = _run(eng)
    obj = tr.to_dict()
    assert validate_trace(obj) == []
    evs = obj["traceEvents"]
    spans = {e["name"] for e in evs if e.get("ph") == "X"}
    assert {"admit", "prefill_dispatch", "decode_tick", "device_dispatch",
            "host_sync", "tick", "admit_loop"} <= spans
    instants = {e["name"] for e in evs if e.get("ph") == "i"}
    assert "first_token" in instants
    # one async begin/end pair per request lifecycle
    begins = [e["id"] for e in evs if e.get("ph") == "b"]
    ends = [e["id"] for e in evs if e.get("ph") == "e"]
    assert sorted(begins) == sorted(ends) == [str(r.rid) for r in reqs]
    if kind == "paged":
        assert any(e.get("ph") == "C" and e["name"] == "pool_pages"
                   for e in evs)
