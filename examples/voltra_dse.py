"""Beyond-paper: design-space exploration with the Voltra model.

The paper fixes one design point (8x8x8 array, 32 banks, 8-deep FIFOs,
128 KB). The calibrated architectural model lets us ask what the paper
could not: how do the utilization/latency claims move across the design
space? Swept here:

  * array shape at iso-MAC (512 MACs): 8x8x8 vs 16x16x2 vs 4x16x8 ...
  * streamer FIFO depth: 1..32
  * shared-memory size: 64..512 KB

  PYTHONPATH=src python examples/voltra_dse.py
"""
import dataclasses

from repro.core import simulator, spatial, temporal, tiling, workloads
from repro.core.accel import VOLTRA

WLS = workloads.all_workloads()


def geomean(xs):
    import math
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))


def sweep_array_shape():
    print("=== array shape @ 512 MACs: geomean spatial utilization ===")
    for (m, n, k) in [(8, 8, 8), (16, 16, 2), (4, 16, 8), (16, 8, 4),
                      (8, 16, 4), (4, 8, 16), (2, 16, 16), (32, 16, 1)]:
        cfg = dataclasses.replace(VOLTRA, array_m=m, array_n=n, array_k=k)
        us = []
        for wl in WLS.values():
            num = den = 0.0
            for op in wl.ops:
                u = spatial.op_spatial_util_3d(op, cfg)
                num += op.macs * u
                den += op.macs
            us.append(num / den)
        print(f"  {m:2d}x{n:2d}x{k:2d}: geomean={geomean(us):.4f} "
              f"min={min(us):.4f}")


def sweep_fifo_depth():
    print("=== FIFO depth: BERT temporal utilization (MGDP) ===")
    wl = WLS["bert_base"]
    for d in (1, 2, 4, 8, 16, 32):
        cfg = dataclasses.replace(VOLTRA, input_fifo_depth=d,
                                  weight_fifo_depth=d)
        u = temporal.workload_temporal_util(wl, cfg=cfg, mgdp=True)
        print(f"  depth {d:2d}: util={u:.4f}")


def sweep_memory_size():
    print("=== shared memory size: ViT-B DMA bytes + latency gain ===")
    for kib in (64, 128, 256, 512):
        cfg = dataclasses.replace(VOLTRA, mem_kib=kib)
        dma = tiling.workload_dma_bytes(WLS["vit_b"], "shared", cfg)
        r = simulator.latency_report(WLS["vit_b"], cfg)
        print(f"  {kib:3d} KiB: shared DMA={dma/1e6:7.1f} MB  "
              f"gain vs separated={r['gain_serial']:.2f}x")


if __name__ == "__main__":
    sweep_array_shape()
    sweep_fifo_depth()
    sweep_memory_size()
    print("DSE done")
