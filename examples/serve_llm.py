"""Serve a small LM with batched requests through the paged KV-cache engine.

Demonstrates: bucketed prefill -> paged cache install -> batched decode ->
continuous batching (more requests than slots) with allocate-on-demand
pages, plus throughput and KV-pool utilization stats. Every request opens
with the same "system prompt", so --prefix-cache shows cross-request KV
sharing (radix-tree match, refcounted pages, suffix-only prefill), and
--spec-k K turns on speculative decode (K drafted tokens verified per
multi-token step by rejection sampling — exact greedy at temperature 0,
distribution-preserving at any --temperature/--top-k/--top-p; add
--draft-model ARCH to draft with a small second model instead of the
built-in n-gram prompt lookup).
Recurrent/hybrid archs (mamba2, recurrentgemma) serve through the SAME
paged engine since ISSUE 5: sliding-window layers use paged ring buffers
with page recycling (O(window) live pages per request), recurrent layers
fixed-size state slots — continuous batching, bucketed prefill and
speculative decode all included.

--shards M serves tensor-parallel over M devices (sharded KV pools +
weights, identical greedy tokens), --replicas R adds data-parallel
whole-engine replicas behind a router; on CPU force the devices with
XLA_FLAGS=--xla_force_host_platform_device_count=N.

--host-tier (with --num-pages small enough to oversubscribe) turns on the
two-tier KV hierarchy: preempted requests swap pages + recurrent state to
host RAM and resume by promotion (prefetched a tick early) instead of
re-prefilling.

  PYTHONPATH=src python examples/serve_llm.py [--arch qwen2.5-3b]
           [--slots 4] [--requests 8] [--max-new 16] [--prefix-cache]
           [--spec-k 4] [--draft-model qwen2.5-3b] [--temperature 0.8]
           [--top-k 40] [--top-p 0.95] [--shards 2] [--replicas 2]
           [--host-tier --num-pages 12] [--trace [trace.json]]
"""
import argparse
import time

import jax

from repro.configs import ARCHS, get_smoke_config
from repro.models import api
from repro.runtime.drafter import DraftModelDrafter
from repro.runtime.router import make_replicas
from repro.runtime.sampling import SamplingParams
from repro.runtime.serving import PagedServingEngine, Request, ServingEngine
from repro.runtime.trace import Tracer, set_default_tracer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=ARCHS)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples (compatible with "
                         "--spec-k: verification rejection-samples)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the K highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass cutoff (1.0 = off)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--paged-attn", choices=["kernel", "gather"],
                    default="kernel",
                    help="decode attention: in-kernel block-table gather "
                         "(Pallas flash-decode) or the dense-gather baseline")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share the common system-prompt KV across "
                         "requests (refcounted copy-on-write pages)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="verify up to K drafted tokens per decode step by "
                         "rejection sampling (exact greedy at temperature "
                         "0, distribution-preserving otherwise)")
    ap.add_argument("--draft-model", default=None, metavar="ARCH",
                    help="draft with a small second model (smoke-sized, "
                         "attention-only arch) instead of n-gram prompt "
                         "lookup; needs --spec-k > 0")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="usable KV pages (default covers slots*max_len; "
                         "set it low with --host-tier to see swapping)")
    ap.add_argument("--host-tier", action="store_true",
                    help="two-tier KV: swap preempted pages + recurrent "
                         "state to host RAM, resume by prefetched "
                         "promotion instead of re-prefill (single shard)")
    ap.add_argument("--shards", type=int, default=1,
                    help="tensor-parallel shards: KV pools + attn/mlp "
                         "weights shard over this many devices")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel engine replicas behind a router "
                         "(each gets --shards devices)")
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="TRACE.JSON",
                    help="record per-tick spans and print the per-phase "
                         "wall breakdown; with a filename, also export "
                         "Chrome Trace Event JSON (open in Perfetto)")
    args = ap.parse_args()

    # engines capture the process-default tracer at construction
    tracer = Tracer(enabled=True) if args.trace is not None else None
    if tracer is not None:
        set_default_tracer(tracer)

    cfg = get_smoke_config(args.arch)
    print(f"[serve] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.slots} slots, {args.requests} requests")
    params = api.init_params(cfg, jax.random.key(0))
    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p).validate()
    drafter = None
    if args.draft_model is not None:
        if args.spec_k <= 0:
            raise SystemExit("--draft-model drafts feed the speculative "
                             "verify step — pass --spec-k > 0 with it")
        if args.draft_model not in ARCHS:
            raise SystemExit(f"--draft-model must be one of {ARCHS}")
        dcfg = get_smoke_config(args.draft_model)
        drafter = DraftModelDrafter(dcfg,
                                    api.init_params(dcfg, jax.random.key(1)),
                                    max_len=128, attn_impl=args.paged_attn)
        print(f"[serve] draft model: {dcfg.name} "
              f"({dcfg.param_count()/1e6:.1f}M params)")
    kw = dict(slots=args.slots, max_len=128, page_size=args.page_size,
              num_pages=args.num_pages, sampling=sampling,
              attn_impl=args.paged_attn, prefix_cache=args.prefix_cache,
              spec_k=args.spec_k, drafter=drafter,
              host_tier=args.host_tier)
    router = None
    if args.replicas > 1:
        router = make_replicas(cfg, params, replicas=args.replicas,
                               model=args.shards, **kw)
        eng = router.engines[0]
        print(f"[serve] router: {args.replicas} x {args.shards}-shard "
              f"replicas on {len(jax.devices())} device(s)")
    else:
        mesh = None
        if args.shards > 1:
            from repro.launch.mesh import make_host_mesh
            mesh = make_host_mesh(model=args.shards,
                                  devices=jax.devices()[:args.shards])
        eng = ServingEngine(cfg, params, mesh=mesh, **kw)
    print(f"[serve] engine: {type(eng).__name__}")

    sys_prompt = [(3 * j + 1) % cfg.vocab for j in range(2 * args.page_size)]
    reqs = [Request(rid=i, prompt=sys_prompt + [(7 * i + j) % cfg.vocab
                                                for j in range(5 + i % 7)],
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    driver = router if router is not None else eng
    done = driver.run_to_completion(reqs, max_steps=2000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    traces = sum(e.prefill_traces for e in router.engines) \
        if router is not None else eng.prefill_traces
    print(f"[serve] {len(done)}/{len(reqs)} done, {toks} tokens in "
          f"{dt:.2f}s ({toks/dt:.1f} tok/s CPU), "
          f"{traces} prefill traces")
    if router is not None:
        rs = router.stats()
        print(f"[serve] routed {rs['routed']}, peak pages per replica "
              f"{[int(p) for p in rs['peak_pages_per_replica']]}")
    if isinstance(eng, PagedServingEngine):
        ss = eng.shard_stats()
        if ss["model_shards"] > 1:
            print(f"[serve] tensor-parallel: {int(ss['model_shards'])} "
                  f"shards ({ss['sharded_axes']}), peak "
                  f"{int(ss['peak_pages_per_shard'])} pages/shard, "
                  f"{int(ss['pool_bytes_per_shard'])} pool bytes/shard")
        st = eng.pool_stats()
        print(f"[serve] kv pool: page={st.page_size} peak "
              f"{st.peak_pages}/{st.num_pages} pages "
              f"({st.peak_pages * st.page_size} tokens reserved at peak vs "
              f"{st.dense_equiv_tokens} dense-slot)")
        if eng.prefix is not None:
            ps = eng.prefix_stats()
            print(f"[serve] prefix cache: {ps['shared_token_frac']:.0%} of "
                  f"prompt tokens reused from cache "
                  f"({ps['prefill_tokens_saved']:.0f} prefill tokens "
                  f"saved, {ps['cow_copies']:.0f} CoW copies, "
                  f"{ps['cached_pages']:.0f} pages cached)")
        if eng.has_win:
            print(f"[serve] sliding window ({eng.window} tokens): "
                  f"{eng.win_recycled_pages} pages recycled in-flight")
        if eng.tier is not None:
            ts = eng.tier_stats()
            print(f"[serve] host tier: {ts['swap_outs']:.0f} swap-outs / "
                  f"{ts['swap_ins']:.0f} swap-ins, "
                  f"{ts['reprefill_tokens_saved']:.0f} re-prefill tokens "
                  f"saved, prefetch hit rate "
                  f"{ts['prefetch_hit_rate']:.2f}")
        if eng.spec_k:
            ss = eng.spec_stats()
            print(f"[serve] speculative (K={eng.spec_k}, drafter "
                  f"{ss['drafter']}): "
                  f"{ss['accepted_per_step']:.2f} tokens/request/step, "
                  f"accept rate {ss['accept_rate']:.2f} "
                  f"({ss['spec_accepted']:.0f}/{ss['spec_drafted']:.0f})")
            if eng.drafter is not None and eng.drafter.kind == "model":
                ds = eng.drafter.stats()
                print(f"[serve] draft model: {ds['draft_proposed']:.0f} "
                      f"proposed / {ds['draft_decode_calls']:.0f} decode "
                      f"calls / {ds['draft_pool_rejects']:.0f} pool "
                      f"rejects")
    m = eng.metrics()
    if not sampling.is_greedy:
        print(f"[serve] decode policy: temperature {sampling.temperature}, "
              f"top_k {sampling.top_k}, top_p {sampling.top_p} — "
              f"{m['sampling.sampled_tokens']:.0f} sampled tokens, "
              f"{m['sampling.step_traces'] + m['sampling.spec_traces']:.0f} "
              f"decode traces (policy-mix invariant)")
    print(f"[serve] latency: ttft p50 {m['latency.ttft_p50_s']:.4f}s / "
          f"p95 {m['latency.ttft_p95_s']:.4f}s, tpot p50 "
          f"{m['latency.tpot_p50_s']:.4f}s / p95 "
          f"{m['latency.tpot_p95_s']:.4f}s, temporal util "
          f"{m['util.temporal']:.2f}")
    if tracer is not None:
        set_default_tracer(None)
        print("[serve] per-phase wall breakdown (nested spans overlap "
              "their parents):")
        print(tracer.format_phase_walls())
        if args.trace:
            tracer.export(args.trace)
            print(f"[serve] wrote {args.trace}: {len(tracer.events())} "
                  f"events — open in Perfetto (https://ui.perfetto.dev)")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt {r.prompt[:4]}... -> "
              f"{r.generated[:8]}{'...' if len(r.generated) > 8 else ''}")
    assert len(done) == len(reqs)
    print("[serve] OK")


if __name__ == "__main__":
    main()
