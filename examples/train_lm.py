"""End-to-end training driver: a ~100M-parameter LM on the synthetic
pipeline with the full production runtime (accumulation, checkpoints,
straggler monitor, resume).

Presets:
  ci      — reduced model, 60 steps, finishes in ~2 min on CPU (default)
  100m    — the ~100M-parameter run (use on real hardware; a few hundred
            steps as the paper-scale end-to-end exercise)

  PYTHONPATH=src python examples/train_lm.py [--preset ci] [--steps N]
           [--ckpt-dir DIR] [--grad-accum N] [--compress-grads]
"""
import argparse

from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, SyntheticDataset
from repro.optim import adamw
from repro.runtime.trainer import Trainer

PRESETS = {
    # ~100M params: 12L x 512d x 8H, ff 2048, vocab 32k
    "100m": ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=512,
        num_heads=8, kv_heads=8, d_ff=2048, vocab=32_000, norm="rmsnorm",
        act="silu", gated_ffn=True),
    "ci": ModelConfig(
        name="lm-ci", family="dense", num_layers=4, d_model=128,
        num_heads=4, kv_heads=2, d_ff=256, vocab=1024, norm="rmsnorm",
        act="silu", gated_ffn=True),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    steps = args.steps or (60 if args.preset == "ci" else 300)
    seq = args.seq or (64 if args.preset == "ci" else 512)
    batch = args.batch or (16 if args.preset == "ci" else 64)
    n_params = cfg.param_count()
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{steps} steps, batch {batch} x seq {seq}")

    ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                     global_batch=batch))
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=max(10, steps // 10),
                            total_steps=steps)
    tr = Trainer(cfg, opt, ds, ckpt_dir=args.ckpt_dir,
                 save_every=max(0, steps // 4) if args.ckpt_dir else 0,
                 grad_accum=args.grad_accum,
                 compress_grads=args.compress_grads, log_every=10)
    tr.run(steps)
    losses = [h["loss"] for h in tr.history]
    print(f"[train_lm] loss {losses[0]:.4f} -> {losses[-1]:.4f} over "
          f"{steps} steps; straggler-flagged {tr.monitor.slow_steps} steps")
    if steps >= 40:   # too few steps to clear warmup = smoke only
        assert losses[-1] < losses[0], "training did not improve the loss"


if __name__ == "__main__":
    main()
