"""Quickstart: the three layers of this repo in ~60 seconds on CPU.

  1. the Voltra architectural model (the paper's claims, reproduced)
  2. the Pallas kernel layer (TPU-native realization, interpret-validated)
  3. the model/runtime layer (assigned architectures, train + serve)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------- 1. chip
from repro.core import simulator, spatial, temporal, workloads

print("=== 1. Voltra architectural model ===")
t1 = simulator.table1()
print(f"peak {t1['peak_tops']:.4f} TOPS | {t1['peak_tops_per_w']:.2f} "
      f"TOPS/W @0.6V | {t1['area_eff_tops_mm2']:.2f} TOPS/mm^2   "
      "(paper: 0.82 / 1.60 / 1.25)")
wl = workloads.bert_base()
print(f"BERT-base: spatial util 3D "
      f"{spatial.workload_spatial_util(wl):.3f} "
      f"(2D {spatial.workload_spatial_util(wl, array='2d'):.3f}), "
      f"temporal MGDP {temporal.workload_temporal_util(wl):.3f} "
      f"(plain {temporal.workload_temporal_util(wl, mgdp=False):.3f})")

# ------------------------------------------------------------- 2. kernels
from repro.kernels import ops, ref

print("\n=== 2. Pallas kernels (interpret mode) ===")
xi = jax.random.randint(jax.random.key(0), (64, 256), -128, 127, jnp.int8)
wi = jax.random.randint(jax.random.key(1), (256, 64), -128, 127, jnp.int8)
got = ops.quant_matmul(xi, wi, 0.002)
np.testing.assert_array_equal(got, ref.gemm_ref(xi, wi, quant_scale=0.002))
print("output-stationary INT8 GeMM + fused quant epilogue: exact vs oracle")

q = jax.random.normal(jax.random.key(2), (1, 64, 8, 32))
k = jax.random.normal(jax.random.key(3), (1, 64, 2, 32))
v = jax.random.normal(jax.random.key(4), (1, 64, 2, 32))
np.testing.assert_allclose(ops.attention(q, k, v, bq=32, bk=32),
                           ref.mha_ref(q, k, v), rtol=3e-3, atol=3e-3)
print("fused flash-MHA (on-the-fly K^T, GQA): allclose vs oracle")

# ------------------------------------------------------- 3. models/runtime
from repro.configs import get_smoke_config
from repro.data.synthetic import DataConfig, SyntheticDataset
from repro.optim import adamw
from repro.runtime.trainer import Trainer

print("\n=== 3. Train a reduced qwen2.5-3b for 30 steps ===")
cfg = get_smoke_config("qwen2.5-3b")
ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq_len=32,
                                 global_batch=8))
tr = Trainer(cfg, adamw.AdamWConfig(lr=3e-3, warmup_steps=10), ds,
             save_every=0, log_every=10)
tr.run(30)
losses = [h["loss"] for h in tr.history]
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} (decreasing: "
      f"{losses[-1] < losses[0]})")
print("\nquickstart OK")
